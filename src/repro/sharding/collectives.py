"""Compressed gradient collectives — the paper's Algorithms realized as
actual mesh communication inside shard_map.

On a TPU mesh there is no parameter server: "each machine sends its
compressed gradient to the server" (Alg. 2) becomes "each data shard feeds
its MLMC residual into a collective over the data axes".  Core schemes:

* ``dense``            — plain f32/bf16 psum (Alg. 1).  Operand bytes: 4d.
* ``mlmc_topk``        — each shard all-gathers only its residual segment
  (s values + s indices) and scatter-adds locally.  Levels are drawn
  INDEPENDENTLY per shard (fold_in of the data index) exactly as Alg. 2/3
  prescribe.
* ``mlmc_fixed``       — the level-l bit-plane residual is a ternary tensor
  {-1,0,+1}: psum it as **int8** (exact for M ≤ 127) and rescale locally.
  Operand bytes: 1d (4x less than dense).  Constraints vs the paper, both
  documented in DESIGN.md: (a) the level draw is SHARED across shards (a
  common-random-numbers variant — unbiasedness is untouched, compression
  noise just stops averaging down in M), because a psum cannot apply
  per-shard scales; (b) the estimator is unbiased w.r.t. the 24-bit
  fixed-point grid value of the gradient (grid error ≤ 2^-24·max|g|).
* ``qsgd`` / ``rtn`` / ``signsgd`` — per-shard single-level baselines: each
  shard compresses locally and the compressed estimates are gathered and
  averaged (the gather keeps the abstract and device substrates bitwise
  comparable; see below).
* ``mlmc_fixed_pershard`` — lifts constraint (a) of ``mlmc_fixed``: each
  shard draws its OWN level and scale (the `MLMCFixedDeviceCodec` lane
  carries both through the gather), so compression noise averages down in
  M again — paid for with a gather instead of the int8 psum.

Selection primitives compose across shards without value gathers:
`global_topk_mask` psums the `repro.kernels.select` byte-radix bucket
counts (4 x 1 KB) to select against GLOBAL magnitude ranks, with
cross-shard threshold ties broken in shard-major canonical order from one
gathered scalar per shard; ``ef21_topk_allreduce(selection="global")``
spends its total s-slot budget on the globally largest innovations.

Wire substrates (``wire=``):

* ``"abstract"`` (default) — residual segments / estimates cross the
  collectives as plain f32/int32/int8 operands; bits are *accounted* from
  the `repro.core.bits` formulas.
* ``"device"`` — operands are bit-packed ON-DEVICE before the collective
  using the `repro.comm.device_wire` fixed-shape packets (Pallas pack
  kernels, no host callbacks, traces under jit + shard_map):
  - ``mlmc_topk`` gathers indices at ceil(log2 d) bits (split planes) and
    bf16 values packed 2-per-word instead of raw int32/f32 — matches the
    abstract direction exactly when the ``bf16_wire`` perf flag is set
    (same value rounding), and within bf16 rounding otherwise;
  - ``mlmc_fixed`` gathers the ternary plane packed at 2 bits/entry
    (the gather variant the ring/hierarchical topologies need; the int8
    psum remains the abstract substrate) — bit-identical direction;
  - ``qsgd`` / ``rtn`` / ``signsgd`` gather packed code words + the f32
    header lane and decode per worker — bit-identical direction.
  Bits are the *measured* static packet operand sizes.

Every function takes and returns a FLAT f32 vector (per-leaf plumbing lives
in `repro.train.step`) and also returns the realized wire-bit count.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bits as bitcost
from repro.core.types import categorical
from repro.kernels import select
from repro.sharding.ctx import ShardCtx

Array = jax.Array

WIRES = ("abstract", "device")


def _check_wire(wire: str) -> None:
    if wire not in WIRES:
        raise ValueError(f"unknown collective wire {wire!r} (one of {WIRES})")


def dense_allreduce(flat: Array, ctx: ShardCtx) -> tuple[Array, Array]:
    """Alg. 1: plain mean over the data axes."""
    mean = ctx.pmean_data(flat)
    bits = jnp.asarray(ctx.dp_total * bitcost.dense_bits(flat.shape[0]),
                       jnp.float32)
    return mean, bits


def _sorted_segments(flat: Array, s: int) -> tuple[Array, int]:
    """One uint32 magnitude-key sort (`kernels.select` canonical order,
    4-5x cheaper than the float argsort it replaced) serving both the
    Lemma-3.4 ladder and the band thresholds of the residual extraction
    (ranks [(l-1)s, ls)).  Returns the descending keys padded with zero
    keys to L*s (rank past d) and L."""
    d = flat.shape[0]
    L = math.ceil(d / s)
    sk = select.sort_magnitude_keys(select.magnitude_keys(flat))
    return jnp.pad(sk, (0, L * s - d)), L


def _segment_ladder(skp: Array, L: int, s: int) -> Array:
    """Residual-norm ladder Delta_l from the sorted/padded keys (the f32
    bitcast is |v| sorted descending, bitwise; squares of the signed
    rank-ordered values are the same bit patterns)."""
    sa = jax.lax.bitcast_convert_type(skp, jnp.float32)
    return jnp.sqrt(jnp.sum(sa.reshape(L, s) ** 2, axis=-1))


def _gather_segment(flat: Array, ctx: ShardCtx, skp: Array,
                    idx0: Array, p_l: Array, *, s: int,
                    wire: str) -> tuple[Array, Array]:
    """Extract this shard's level-(idx0+1) residual segment, cross the data
    axes (raw f32/int32 operands or the bit-packed device form), scatter-add
    and mean.  Shared by the stateless Alg.-3 path and the stateful EMA
    variant — the wire is identical, only the level distribution differs."""
    d = flat.shape[0]
    seg_idx, valid = select.rank_band_indices(flat, idx0 * s, s,
                                              sorted_keys=skp)
    # padded tail entries carry index d-1 (the packed index must stay in
    # range); their value must be 0
    seg_idx = jnp.where(valid, seg_idx, d - 1)
    seg_vals = jnp.where(valid, flat[seg_idx] / p_l, 0.0)

    from repro import perf

    if wire == "device":
        from repro.comm.device_wire import (pack_topk_segment,
                                            topk_segment_words,
                                            unpack_topk_segment)

        # bf16 values 2/word + ceil(log2 d)-bit split-plane indices: the
        # same rounding the abstract path applies under `bf16_wire`
        words = pack_topk_segment(seg_vals, seg_idx, d, 16)
        g_words = ctx.gather_data_stack(words)                # (M, W) uint32
        g_vals, g_idx = jax.vmap(
            lambda w: unpack_topk_segment(w, d, s, 16))(g_words)
        g_vals, g_idx = g_vals.reshape(-1), g_idx.reshape(-1)
        bits = jnp.asarray(
            ctx.dp_total * 32.0 * topk_segment_words(d, s, 16), jnp.float32)
    else:
        value_bits = 32
        if perf.enabled("bf16_wire"):
            # §Perf `bf16_wire`: residual values cross the gather in bf16
            # (8 -> 6 bytes/entry with the int32 index)
            seg_vals = seg_vals.astype(jnp.bfloat16)
            value_bits = 16
        g_vals = ctx.gather_data_stack(seg_vals).reshape(-1)      # (M*s,)
        g_idx = ctx.gather_data_stack(seg_idx).reshape(-1)
        bits = jnp.asarray(
            ctx.dp_total * bitcost.topk_mlmc_bits(d, s,
                                                  value_bits=value_bits),
            jnp.float32)

    dense = jnp.zeros((d,), flat.dtype).at[g_idx].add(
        g_vals.astype(flat.dtype))
    mean = dense / ctx.dp_total
    return mean, bits


def mlmc_topk_allreduce(flat: Array, ctx: ShardCtx, rng: Array,
                        *, s: int, wire: str = "abstract"
                        ) -> tuple[Array, Array]:
    """Adaptive MLMC s-Top-k (Alg. 3) with a sparse all-gather collective.
    Levels are drawn INDEPENDENTLY per shard (fold_in of the data index)
    from the per-sample Lemma-3.4 distribution.

    ``wire="device"``: the segment crosses the gather bit-packed — indices
    at ceil(log2 d) bits, values in bf16 2-per-word (`repro.comm.
    device_wire.pack_topk_segment`)."""
    from repro.core.adaptive import probs_from_ladder

    d = flat.shape[0]
    s = min(s, d)
    rng = jax.random.fold_in(rng, ctx.data_index())  # independent levels
    skp, L = _sorted_segments(flat, s)

    deltas = _segment_ladder(skp, L, s)                          # Lemma 3.4
    probs = probs_from_ladder(deltas)
    idx0 = categorical(rng, probs)                                # 0-based l-1
    p_l = jnp.maximum(probs[idx0], 1e-30)
    return _gather_segment(flat, ctx, skp, idx0, p_l, s=s, wire=wire)


def mlmc_adaptive_topk_allreduce(flat: Array, ctx: ShardCtx, rng: Array,
                                 ladder: Array, step: Array, *, s: int,
                                 ema_rho: float = 0.25,
                                 wire: str = "abstract"
                                 ) -> tuple[Array, Array, Array]:
    """The STATEFUL Alg.-3 variant on the mesh: each data shard keeps an
    EMA of its residual-norm ladder (`CommState.ladder_ema`'s mesh
    realization, threaded through the train step as a per-leaf, per-shard
    pytree) and samples its level from the smoothed Lemma-3.4 distribution.

    Returns ``(mean, bits, new_ladder)``; the caller threads ``new_ladder``
    into the next step.  The wire — segment gather, raw or bit-packed —
    is byte-identical to `mlmc_topk_allreduce`; only the level distribution
    is stateful, so the device substrate needs no new packet form (p_l is
    applied shard-locally before the gather, exactly as in the stateless
    path)."""
    from repro.core.adaptive import ladder_ema_update, probs_from_ladder

    d = flat.shape[0]
    s = min(s, d)
    rng = jax.random.fold_in(rng, ctx.data_index())  # independent levels
    skp, L = _sorted_segments(flat, s)

    deltas = _segment_ladder(skp, L, s)
    new_ladder = ladder_ema_update(ladder.reshape(L), deltas, ema_rho, step)
    probs = probs_from_ladder(new_ladder)
    idx0 = categorical(rng, probs)
    p_l = jnp.maximum(probs[idx0], 1e-30)
    mean, bits = _gather_segment(flat, ctx, skp, idx0, p_l, s=s, wire=wire)
    return mean, bits, new_ladder.reshape(ladder.shape)


def adaptive_segment_len(d: int, k_fraction: float,
                         min_segment: int = 8) -> int:
    """Segment length s for a leaf of flat size d — the ONE definition the
    dispatches and the comm-state builder share, so the threaded ladder
    shape always matches the collective's segmentation."""
    return min(max(min_segment, int(round(k_fraction * d))), d)


def adaptive_ladder_len(d: int, k_fraction: float,
                        min_segment: int = 8) -> int:
    """Ladder length L = ceil(d / s) for a leaf of flat size d."""
    return math.ceil(d / adaptive_segment_len(d, k_fraction, min_segment))


def mlmc_fixedpoint_allreduce(flat: Array, ctx: ShardCtx, rng: Array,
                              *, num_levels: int = 24, wire: str = "abstract"
                              ) -> tuple[Array, Array]:
    """Fixed-point MLMC (Alg. 2, Lemma 3.3) with an int8 psum collective.

    ``wire="device"``: the ternary plane crosses a gather packed at 2
    bits/entry instead of the int8 psum — 4x fewer operand bytes per shard,
    and the form ring/hierarchical topologies forward verbatim.  The summed
    integers are identical, so the direction is bit-identical to the psum."""
    d = flat.shape[0]
    L = num_levels

    # shared scale (one scalar collective) + shared level draw (common rng)
    gmax = ctx.pmax_data(jnp.max(jnp.abs(flat)))
    gmax = jnp.maximum(gmax, 1e-30)
    probs = 2.0 ** -jnp.arange(1, L + 1, dtype=jnp.float32)
    probs = probs / jnp.sum(probs)
    idx0 = categorical(rng, probs)
    level = idx0 + 1
    p_l = probs[idx0]

    x = jnp.minimum(jnp.abs(flat) / gmax, 1.0 - 2.0 ** -24)
    bit = jnp.mod(jnp.floor(jnp.ldexp(x, level)), 2.0)
    tern = (jnp.sign(flat) * bit).astype(jnp.int8)

    if wire == "device":
        from repro.comm.device_wire import (pack_ternary, ternary_words,
                                            unpack_ternary)

        words = pack_ternary(tern)                           # 2 bits/entry
        g_words = ctx.gather_data_stack(words)               # (M, W) uint32
        summed = jnp.sum(jax.vmap(lambda w: unpack_ternary(w, d))(g_words),
                         axis=0)
        bits = jnp.asarray(
            ctx.dp_total * (32.0 * ternary_words(d) + 64.0), jnp.float32)
    else:
        summed = ctx.psum_data(tern)                         # int8 wire
        bits = jnp.asarray(
            ctx.dp_total * bitcost.fixed_point_mlmc_bits(d, L), jnp.float32)

    scale = gmax * jnp.ldexp(1.0, -level) / (p_l * ctx.dp_total)
    mean = summed.astype(jnp.float32) * scale
    return mean, bits


def _codec_allreduce(flat: Array, ctx: ShardCtx, rng: Array, codec,
                     wire: str) -> tuple[Array, Array]:
    """Shared path for the per-shard single-level baselines (qsgd / rtn /
    signsgd): compress locally with a `repro.comm.device_wire` codec, gather
    either the dense estimates (abstract) or the packed words + header lane
    (device), and average the per-worker estimates.  Both substrates apply
    the identical `jnp.mean` over the identical per-worker values, so the
    directions match bitwise."""
    from repro.comm.device_wire import DevicePacket

    rng = jax.random.fold_in(rng, ctx.data_index())  # per-shard randomness
    packet, est = codec.encode(flat, rng)
    if wire == "device":
        g_words = ctx.gather_data_stack(packet.words)
        g_lane = ctx.gather_data_stack(packet.lane)
        ests = jax.vmap(
            lambda w, ln: codec.decode(DevicePacket(w, ln)))(g_words, g_lane)
        bits = jnp.asarray(ctx.dp_total * codec.operand_bits(), jnp.float32)
    else:
        ests = ctx.gather_data_stack(est)
        bits = jnp.asarray(ctx.dp_total * codec.nominal_bits(), jnp.float32)
    return jnp.mean(ests, axis=0), bits


def global_topk_mask(u: Array, k, ctx: ShardCtx) -> Array:
    """EXACT membership mask of this shard's entries in the GLOBAL top-k
    of the shard-major concatenation of ``u`` across the data axes —
    selected from psum'd bucket counts, never gathering values.

    `kernels.select.histogram_threshold` walks four 256-ary byte
    histograms of the uint32 magnitude keys with each histogram psum'd
    across shards (4 x 1 KB on the interconnect), yielding the exact
    global rank-k threshold key.  Cross-shard ties at the threshold are
    broken in canonical order — ascending global index, i.e. ascending
    (data shard index, local index) — from one gathered scalar tie count
    per shard.  With ``ctx`` unsharded this degenerates to the local
    `topk_mask` bit for bit."""
    keys = select.magnitude_keys(u)
    k = jnp.asarray(k, jnp.int32)
    t = select.histogram_threshold(keys, k - 1, reduce=ctx.psum_data)
    gt = keys > t
    eq = keys == t
    n_gt = ctx.psum_data(jnp.sum(gt.astype(jnp.int32)))
    n_eq = jnp.sum(eq.astype(jnp.int32))
    tie_counts = ctx.gather_data_stack(n_eq).reshape(-1)     # (dp_total,)
    ties_before = jnp.sum(jnp.where(
        jnp.arange(tie_counts.shape[0]) < ctx.data_index(), tie_counts, 0))
    take = jnp.clip(k - n_gt - ties_before, 0, n_eq)
    occ = jnp.cumsum(eq.astype(jnp.int32)) - 1               # tie occurrence
    return gt | (eq & (occ < take))


EF21_SELECTIONS = ("shard", "global")


def ef21_topk_allreduce(flat: Array, ctx: ShardCtx, mirror: Array,
                        server: Array, *, s: int, wire: str = "abstract",
                        selection: str = "shard"
                        ) -> tuple[Array, Array, Array, Array]:
    """EF21 (Richtárik et al., 2021) as a mesh collective: each data shard
    keeps a dense mirror ``g_i`` of its own compressed history plus a
    replica of the server aggregate ``g = mean_i g_i``, Top-k-compresses
    the innovation ``grad_i - g_i``, and gathers the sparse innovations
    over the data axes.  Every shard applies the identical gathered mean
    to its server replica, so the replicas stay bitwise in sync without a
    dense collective — the mesh realization of the trainer's
    ``CommState.g_workers`` / ``g_server``, threaded through the train
    step exactly the way the adaptive ladder rides (see
    `repro.train.step.init_mesh_comm_state`).

    The mirror advances by the DECODED innovation — what actually crossed
    the wire — so the EF21 contraction holds on the lossy ``"device"``
    substrate (bf16-packed values) just as on the raw f32 gather.

    ``selection="global"`` selects the s globally-largest innovation
    entries ACROSS all data shards (via `global_topk_mask`'s psum'd bucket
    counts — no value gather) instead of s per shard: the wire form is
    unchanged (each shard's s slots carry its members of the global set,
    zero-padded), total traffic buys the best s entries anywhere in the
    fleet, and the mirror still advances only by what this shard shipped.

    Returns ``(direction, bits, new_mirror, new_server)``."""
    if selection not in EF21_SELECTIONS:
        raise ValueError(f"unknown ef21 selection {selection!r} "
                         f"(one of {EF21_SELECTIONS})")
    d = flat.shape[0]
    mirror_shape, server_shape = mirror.shape, server.shape
    mirror = mirror.reshape(d).astype(flat.dtype)
    server = server.reshape(d).astype(flat.dtype)

    u = flat - mirror
    if selection == "global":
        member = global_topk_mask(u, s, ctx)
        # members in rank order out of one masked s-sized top_k; empty
        # slots point at d-1 with value 0 (the packed index stays in range)
        _, idx = lax.top_k(jnp.where(member, jnp.abs(u), -1.0), s)
        valid = jnp.arange(s) < jnp.sum(member.astype(jnp.int32))
        idx = jnp.where(valid, idx, d - 1)
        vals = jnp.where(valid, u[idx], 0.0)
    else:
        _, idx = lax.top_k(jnp.abs(u), s)
        vals = u[idx]

    if wire == "device":
        from repro.comm.device_wire import (pack_topk_segment,
                                            topk_segment_words,
                                            unpack_topk_segment)

        words = pack_topk_segment(vals, idx, d, 16)
        g_words = ctx.gather_data_stack(words)                # (M, W) uint32
        g_vals, g_idx = jax.vmap(
            lambda w: unpack_topk_segment(w, d, s, 16))(g_words)
        g_vals, g_idx = g_vals.reshape(-1), g_idx.reshape(-1)
        # the mirror must track the server's view: use the decoded values
        own_vals, own_idx = unpack_topk_segment(words, d, s, 16)
        bits = jnp.asarray(
            ctx.dp_total * 32.0 * topk_segment_words(d, s, 16), jnp.float32)
    else:
        g_vals = ctx.gather_data_stack(vals).reshape(-1)
        g_idx = ctx.gather_data_stack(idx).reshape(-1)
        own_vals, own_idx = vals, idx
        bits = jnp.asarray(ctx.dp_total * bitcost.ef21_bits(d, s),
                           jnp.float32)

    mean_c = jnp.zeros((d,), flat.dtype).at[g_idx].add(
        g_vals.astype(flat.dtype)) / ctx.dp_total
    new_mirror = mirror.at[own_idx].add(own_vals.astype(flat.dtype))
    new_server = server + mean_c
    return (new_server, bits, new_mirror.reshape(mirror_shape),
            new_server.reshape(server_shape))


AGG_METHODS = ("dense", "mlmc_topk", "mlmc_fixed", "mlmc_fixed_pershard",
               "qsgd", "rtn", "signsgd", "mlmc_adaptive_topk", "ef21")

#: methods with a `wire="device"` packed-collective branch
DEVICE_METHODS = ("mlmc_topk", "mlmc_fixed", "mlmc_fixed_pershard", "qsgd",
                  "rtn", "signsgd", "mlmc_adaptive_topk", "ef21")

#: methods whose mesh collective threads per-shard comm state (see
#: `repro.train.step.init_mesh_comm_state` for the pytree layout)
STATEFUL_MESH_METHODS = ("mlmc_adaptive_topk", "ef21")

#: the error-feedback subset: per-leaf state is (dense mirror, server
#: replica) instead of the EMA residual-norm ladder
EF_MESH_METHODS = ("ef21",)


def compressed_allreduce(flat: Array, ctx: ShardCtx, rng: Array,
                         method: str, *, k_fraction: float = 0.001,
                         min_segment: int = 8, wire: str = "abstract",
                         qsgd_levels: int = 2, rtn_level: int = 4
                         ) -> tuple[Array, Array]:
    """Dispatch.  For mlmc_topk the per-leaf segment budget is
    ``s = max(min_segment, k_fraction * d)`` — one MLMC residual segment of
    roughly the Top-k budget the paper uses (k ∈ {0.001n .. 0.5n}).

    ``wire`` selects the collective substrate (see module docstring):
    ``"abstract"`` ships raw f32/int32/int8 operands, ``"device"``
    bit-packs operands on-device before the collective."""
    _check_wire(wire)
    if method == "dense":
        return dense_allreduce(flat, ctx)
    if method in STATEFUL_MESH_METHODS:
        raise ValueError(
            f"{method!r} threads per-shard comm state — call "
            "stateful_allreduce / ef21_topk_allreduce "
            "(repro.train.step.make_train_step wires it up)")
    if method == "mlmc_topk":
        s = max(min_segment, int(round(k_fraction * flat.shape[0])))
        return mlmc_topk_allreduce(flat, ctx, rng, s=s, wire=wire)
    if method == "mlmc_fixed":
        return mlmc_fixedpoint_allreduce(flat, ctx, rng, wire=wire)
    if method == "mlmc_fixed_pershard":
        # lifts shared-level constraint (a) of the psum path: each shard
        # draws its OWN level and scale (the `MLMCFixedDeviceCodec` lane
        # carries both), so compression noise averages down in M again —
        # paid for with a gather instead of the int8 psum
        from repro.comm.device_wire import MLMCFixedDeviceCodec

        codec = MLMCFixedDeviceCodec(flat.shape[0])
        return _codec_allreduce(flat, ctx, rng, codec, wire)
    if method in ("qsgd", "rtn", "signsgd"):
        from repro.comm.device_wire import make_device_codec

        codec = make_device_codec(method, flat.shape[0],
                                  qsgd_levels=qsgd_levels,
                                  rtn_level=rtn_level)
        return _codec_allreduce(flat, ctx, rng, codec, wire)
    raise ValueError(f"unknown aggregation method {method!r}")


def stateful_allreduce(flat: Array, ctx: ShardCtx, rng: Array, method: str,
                       ladder: Array, step: Array, *,
                       k_fraction: float = 0.001, min_segment: int = 8,
                       ema_rho: float = 0.25, wire: str = "abstract"
                       ) -> tuple[Array, Array, Array]:
    """Dispatch for the stateful mesh methods: like `compressed_allreduce`
    but threading this shard's per-leaf comm state (the EMA ladder) and
    returning its successor — (mean, bits, new_ladder)."""
    _check_wire(wire)
    if method == "mlmc_adaptive_topk":
        s = adaptive_segment_len(flat.shape[0], k_fraction, min_segment)
        return mlmc_adaptive_topk_allreduce(flat, ctx, rng, ladder, step,
                                            s=s, ema_rho=ema_rho, wire=wire)
    if method in EF_MESH_METHODS:
        raise ValueError(
            f"{method!r} threads (mirror, server) state, not a ladder — "
            "call ef21_topk_allreduce(flat, ctx, mirror, server, ...)")
    raise ValueError(f"unknown stateful aggregation method {method!r}")
