"""repro.sharding — explicit parallel context, partitioning rules and the
compressed gradient collectives."""

from repro.sharding.ctx import ShardCtx, unsharded
from repro.sharding.partition import (
    fsdp_axes,
    fsdp_gather,
    param_specs,
    shard_params_like,
)

__all__ = ["ShardCtx", "fsdp_axes", "fsdp_gather", "param_specs",
           "shard_params_like", "unsharded"]
