"""repro.sharding — explicit parallel context, partitioning rules and the
compressed gradient collectives."""

import jax as _jax

from repro.sharding.ctx import ShardCtx, unsharded
from repro.sharding.partition import (
    fsdp_axes,
    fsdp_gather,
    param_specs,
    shard_params_like,
)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """Version-compat `shard_map`.

    jax >= 0.6 exposes `jax.shard_map` with a `check_vma` kwarg; older
    releases (this container ships 0.4.x) only have
    `jax.experimental.shard_map.shard_map`, where the same knob is called
    `check_rep`.  All repo code and tests route through this wrapper.
    """
    if hasattr(_jax, "shard_map"):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return _jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


__all__ = ["ShardCtx", "fsdp_axes", "fsdp_gather", "param_specs",
           "shard_map", "shard_params_like", "unsharded"]
