from repro.data.synthetic import (
    LMTask,
    TeacherTask,
    flatten_worker_batch,
    lm_batches,
    teacher_student,
)

__all__ = ["LMTask", "TeacherTask", "flatten_worker_batch", "lm_batches",
           "teacher_student"]
