"""Synthetic data pipelines.

Two task families, both with enough learnable structure that the paper's
compression methods separate on loss-vs-bits curves:

* `lm_task` — token sequences from a noisy affine recurrence
  ``x_{t+1} = (a * x_t + c) mod V`` with per-worker (a, c) drift in the
  heterogeneous variant (the paper's ξ > 0 setting).
* `teacher_student` — regression against a frozen random MLP teacher
  (the smooth/convex-ish setting of Theorem 2.3 / 4.1 checks).

Batches are yielded with a leading worker axis (M, b, ...) for
`repro.train.loop.Trainer`; the flat variant feeds the mesh runtime."""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LMTask:
    vocab: int = 256
    seq: int = 64
    noise: float = 0.05       # probability a token is replaced uniformly
    heterogeneity: float = 0.0  # worker-distribution drift (paper's xi)


def lm_batches(task: LMTask, num_workers: int, batch_per_worker: int,
               seed: int = 0) -> Iterator[dict]:
    """Yields {"tokens": (M,b,S), "labels": (M,b,S)} forever."""
    rng = jax.random.PRNGKey(seed)
    # per-worker recurrence params; heterogeneity tilts them apart
    base_a, base_c = 5, 17
    workers = jnp.arange(num_workers)
    a = base_a + (workers * jnp.int32(task.heterogeneity * 3)) % 11
    c = base_c + (workers * jnp.int32(task.heterogeneity * 7)) % 13

    @jax.jit
    def make(key):
        k0, kn, ku = jax.random.split(key, 3)
        x0 = jax.random.randint(k0, (num_workers, batch_per_worker),
                                0, task.vocab)

        def step(x, _):
            nxt = (a[:, None] * x + c[:, None]) % task.vocab
            return nxt, nxt

        _, seq = jax.lax.scan(step, x0, None, length=task.seq)
        toks = jnp.moveaxis(seq, 0, -1)                     # (M, b, S)
        flip = jax.random.bernoulli(kn, task.noise, toks.shape)
        rand = jax.random.randint(ku, toks.shape, 0, task.vocab)
        toks = jnp.where(flip, rand, toks)
        labels = jnp.roll(toks, -1, axis=-1).at[..., -1].set(0)
        return {"tokens": toks, "labels": labels}

    while True:
        rng, sub = jax.random.split(rng)
        yield make(sub)


@dataclasses.dataclass(frozen=True)
class TeacherTask:
    d_in: int = 32
    d_hidden: int = 64
    d_out: int = 1
    noise: float = 0.01


def teacher_student(task: TeacherTask, num_workers: int,
                    batch_per_worker: int, seed: int = 0) -> Iterator[dict]:
    """Yields {"x": (M,b,d_in), "y": (M,b,d_out)} from a frozen teacher."""
    rng = jax.random.PRNGKey(seed + 1234)
    kw1, kw2, rng = jax.random.split(rng, 3)
    w1 = jax.random.normal(kw1, (task.d_in, task.d_hidden)) / task.d_in**0.5
    w2 = jax.random.normal(kw2, (task.d_hidden, task.d_out)) / task.d_hidden**0.5

    @jax.jit
    def make(key):
        kx, kn = jax.random.split(key)
        x = jax.random.normal(kx, (num_workers, batch_per_worker, task.d_in))
        y = jnp.tanh(x @ w1) @ w2
        y = y + task.noise * jax.random.normal(kn, y.shape)
        return {"x": x, "y": y}

    while True:
        rng, sub = jax.random.split(rng)
        yield make(sub)


def flatten_worker_batch(batch: dict) -> dict:
    """(M, b, ...) -> (M*b, ...) for non-worker-aware consumers."""
    return jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), batch)
