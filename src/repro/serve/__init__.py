from repro.serve.engine import Engine, ServeResult

__all__ = ["Engine", "ServeResult"]
