"""Batched decode engine: prefill once, then greedy decode steps with the
per-layer caches (KV / latent / SSM-state / LRU-state) threaded through.

Works both unsharded (CPU examples/tests) and over a mesh (pass the step
functions built by `repro.train.step`)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.sharding.ctx import ShardCtx, unsharded

PyTree = Any


@dataclasses.dataclass
class ServeResult:
    tokens: jax.Array          # (B, n_generated)
    prefill_len: int


class Engine:
    """Single-host serving engine over a Model."""

    def __init__(self, model: Model, params: PyTree,
                 ctx: ShardCtx | None = None):
        self.model = model
        self.params = params
        self.ctx = ctx or unsharded()
        self._decode = jax.jit(
            lambda tok, pos, caches, enc: model.decode_step(
                self.params, tok, pos, caches, self.ctx, enc))

    def generate(self, batch: dict, *, max_new_tokens: int,
                 cache_len: int | None = None) -> ServeResult:
        """batch: {"tokens": (B, S_prompt), [modality inputs]}."""
        prompt = batch["tokens"]
        b, s = prompt.shape
        cache_len = cache_len or (s + max_new_tokens)
        caches, nxt, enc_out = self.model.prefill(
            self.params, batch, cache_len, self.ctx)

        toks = [nxt]
        tok = nxt
        for i in range(max_new_tokens - 1):
            pos = jnp.int32(s + i)
            tok, caches = self._decode(tok, pos, caches, enc_out)
            toks.append(tok)
        return ServeResult(tokens=jnp.stack(toks, axis=1), prefill_len=s)
