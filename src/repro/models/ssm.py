"""Mamba2 block with the SSD (state-space duality) chunked algorithm
[arXiv:2405.21060].

The SSD form computes the selective-SSM scan as block matmuls (MXU-friendly,
the whole point of state-space *duality*): within-chunk outputs use the
quadratic-in-chunk masked kernel, inter-chunk state is carried by a short
`lax.scan` over chunks — O(S·chunk) FLOPs, O(S/chunk) sequential steps.

TP: heads (d_inner = expand*d_model) are sharded over ``model``; B/C are
per-group (n_groups = 1 ⇒ replicated);  out_proj is row-parallel (+psum).

Decode keeps the O(1) recurrent state h (B, H, P, N) + conv tail.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    causal_conv1d,
    causal_conv1d_step,
    col_linear,
    dense_init,
    rms_norm,
    sharded_rms_norm,
    rms_norm_params,
    row_linear,
)
from repro.sharding.ctx import ShardCtx

Array = jax.Array


class SSDCache(NamedTuple):
    state: Array       # (B, Hl, P, N) recurrent state
    conv_x: Array      # (B, K-1, d_inner_local) conv tail for x
    conv_b: Array      # (B, K-1, G*N)
    conv_c: Array      # (B, K-1, G*N)


def ssd_params(cfg: ModelConfig, key, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    din = s.expand * d
    nheads = din // s.head_dim
    gn = s.n_groups * s.d_state
    ks = jax.random.split(key, 9)
    return {
        # column-parallel (sharded over model on the head/channel dim)
        "w_x": dense_init(ks[0], d, din, dtype),
        "w_z": dense_init(ks[1], d, din, dtype),
        "w_dt": dense_init(ks[2], d, nheads, dtype),
        # replicated (groups are tiny)
        "w_b": dense_init(ks[3], d, gn, dtype),
        "w_c": dense_init(ks[4], d, gn, dtype),
        # depthwise conv taps
        "conv_x": (jax.random.normal(ks[5], (s.d_conv, din), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": (jax.random.normal(ks[6], (s.d_conv, gn), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_c": (jax.random.normal(ks[7], (s.d_conv, gn), jnp.float32)
                   * 0.1).astype(dtype),
        # per-head decay/skip/dt-bias (sharded over model with the heads)
        "a_log": jnp.zeros((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "gnorm": rms_norm_params(din, dtype),
        # row-parallel out
        "w_out": dense_init(ks[8], din, d, dtype),
    }


def _segsum(a: Array) -> Array:
    """Lower-triangular pairwise cumulative sums: out[..., i, j] =
    sum(a[..., j+1:i+1]) for j <= i, -inf above the diagonal."""
    t = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x: Array, dt: Array, a_log: Array, b: Array, c: Array,
             chunk: int, init_state: Array | None = None):
    """Chunked SSD.  x: (B,S,H,P); dt: (B,S,H); b,c: (B,S,G,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    reps = h // g
    nc = s // chunk
    assert nc * chunk == s, "seq must divide by chunk"

    a = -jnp.exp(a_log)[None, None, :] * dt                  # (B,S,H) log-decay
    xb = x.reshape(bsz, nc, chunk, h, p)
    dtb = dt.reshape(bsz, nc, chunk, h)
    ab = a.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2)  # (B,H,nc,L)
    bb = b.reshape(bsz, nc, chunk, g, n)
    cb = c.reshape(bsz, nc, chunk, g, n)
    bh = jnp.repeat(bb, reps, axis=3)                        # (B,nc,L,H,N)
    ch = jnp.repeat(cb, reps, axis=3)

    a_cs = jnp.cumsum(ab, axis=-1)                           # (B,H,nc,L)
    # 1. within-chunk (diagonal) term
    L = jnp.exp(_segsum(ab))                                 # (B,H,nc,L,L)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp,bcsh->bclhp",
                        ch, bh, L, xb, dtb)
    # 2. per-chunk end states
    decay_to_end = jnp.exp(a_cs[..., -1:] - a_cs)            # (B,H,nc,L)
    states = jnp.einsum("bclhn,bhcl,bclhp,bclh->bchpn",
                        bh, decay_to_end, xb, dtb)
    # 3. inter-chunk recurrence (sequential over chunks)
    chunk_decay = jnp.exp(a_cs[..., -1])                     # (B,H,nc)

    def body(h_prev, inp):
        st, dec = inp                                        # (B,H,P,N),(B,H)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    h0 = (init_state if init_state is not None
          else jnp.zeros((bsz, h, p, n), x.dtype))
    final, prev_states = jax.lax.scan(
        body, h0.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (B,nc,H,P,N)
    # 4. state -> output within each chunk
    state_decay = jnp.exp(a_cs)                              # (B,H,nc,L)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp",
                       ch, prev_states.astype(x.dtype), state_decay)
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final.astype(x.dtype)


def ssd_sequence(params: dict, cfg: ModelConfig, x: Array, ctx: ShardCtx,
                 want_cache: bool):
    """Full-sequence Mamba2 block.  x: (B,S,d)."""
    s_cfg = cfg.ssm
    bsz, s, _ = x.shape
    hd = s_cfg.head_dim
    xin = col_linear(x, params["w_x"])                       # (B,S,din_l)
    z = col_linear(x, params["w_z"])
    dt = jax.nn.softplus(col_linear(x, params["w_dt"]).astype(jnp.float32)
                         + params["dt_bias"])                # (B,S,Hl)
    b = causal_conv1d(col_linear(x, params["w_b"]), params["conv_b"])
    c = causal_conv1d(col_linear(x, params["w_c"]), params["conv_c"])
    xin = jax.nn.silu(causal_conv1d(xin, params["conv_x"]))
    b = jax.nn.silu(b)
    c = jax.nn.silu(c)

    hl = xin.shape[-1] // hd
    xh = xin.reshape(bsz, s, hl, hd)
    bg = b.reshape(bsz, s, s_cfg.n_groups, s_cfg.d_state)
    cg = c.reshape(bsz, s, s_cfg.n_groups, s_cfg.d_state)

    chunk = min(s_cfg.chunk, s)
    y, final = ssd_scan(xh, dt, params["a_log"], bg, cg, chunk)
    y = y + xh * params["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, -1) * jax.nn.silu(z)
    y = sharded_rms_norm(y, params["gnorm"], ctx)
    out = row_linear(y, params["w_out"], ctx)

    cache = None
    if want_cache:
        k = s_cfg.d_conv - 1
        cache = SSDCache(
            state=final,
            conv_x=col_linear(x[:, -k:, :], params["w_x"]),
            conv_b=col_linear(x[:, -k:, :], params["w_b"]),
            conv_c=col_linear(x[:, -k:, :], params["w_c"]),
        )
    return out, cache


def init_ssd_cache(batch: int, cfg: ModelConfig, ctx: ShardCtx,
                   dtype) -> SSDCache:
    s = cfg.ssm
    din_l = (s.expand * cfg.d_model) // ctx.tp
    hl = din_l // s.head_dim
    gn = s.n_groups * s.d_state
    k = s.d_conv - 1
    return SSDCache(
        state=jnp.zeros((batch, hl, s.head_dim, s.d_state), dtype),
        conv_x=jnp.zeros((batch, k, din_l), dtype),
        conv_b=jnp.zeros((batch, k, gn), dtype),
        conv_c=jnp.zeros((batch, k, gn), dtype),
    )


def ssd_decode(params: dict, cfg: ModelConfig, x1: Array, cache: SSDCache,
               ctx: ShardCtx):
    """Single-token recurrent step.  x1: (B, d)."""
    s_cfg = cfg.ssm
    hd = s_cfg.head_dim
    bsz = x1.shape[0]

    x_raw = col_linear(x1, params["w_x"])
    b_raw = col_linear(x1, params["w_b"])
    c_raw = col_linear(x1, params["w_c"])
    z = col_linear(x1, params["w_z"])
    dt = jax.nn.softplus(col_linear(x1, params["w_dt"]).astype(jnp.float32)
                         + params["dt_bias"])                # (B, Hl)

    xc, conv_x = causal_conv1d_step(x_raw, cache.conv_x, params["conv_x"])
    bc, conv_b = causal_conv1d_step(b_raw, cache.conv_b, params["conv_b"])
    cc, conv_c = causal_conv1d_step(c_raw, cache.conv_c, params["conv_c"])
    xc = jax.nn.silu(xc)
    bc = jax.nn.silu(bc)
    cc = jax.nn.silu(cc)

    hl = xc.shape[-1] // hd
    xh = xc.reshape(bsz, hl, hd)
    bg = bc.reshape(bsz, s_cfg.n_groups, s_cfg.d_state)
    cg = cc.reshape(bsz, s_cfg.n_groups, s_cfg.d_state)
    reps = hl // s_cfg.n_groups
    bh = jnp.repeat(bg, reps, axis=1)                        # (B, Hl, N)
    chh = jnp.repeat(cg, reps, axis=1)

    decay = jnp.exp(-jnp.exp(params["a_log"]) * dt)          # (B, Hl)
    state = (cache.state * decay[..., None, None]
             + jnp.einsum("bhp,bhn,bh->bhpn", xh, bh, dt).astype(cache.state.dtype))
    y = jnp.einsum("bhpn,bhn->bhp", state.astype(jnp.float32),
                   chh.astype(jnp.float32)).astype(x1.dtype)
    y = y + xh * params["d_skip"][None, :, None]
    y = y.reshape(bsz, -1) * jax.nn.silu(z)
    y = sharded_rms_norm(y, params["gnorm"], ctx)
    out = row_linear(y, params["w_out"], ctx)
    return out, SSDCache(state, conv_x, conv_b, conv_c)