"""Shared neural-net layers: norms, rotary embeddings, init helpers, and the
tensor-parallel linear/embedding primitives used by every architecture.

Tensor-parallel convention (Megatron-style, manual inside one shard_map):

* column-parallel weights shard their OUTPUT features over the ``model`` axis
  (the caller sees a local slice; no collective needed),
* row-parallel weights shard their INPUT features; the caller must ``psum``
  the product over ``model`` (we fold that into `row_linear`),
* activations between layers are replicated across ``model`` and sharded over
  ``data``/``pod`` on the batch dim,
* model code NEVER consults the mesh — local shapes come from the (possibly
  sliced) param arrays themselves, collectives go through `ShardCtx`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.ctx import ShardCtx

Array = jax.Array


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def rms_norm_params(dim: int, dtype) -> Array:
    return jnp.zeros((dim,), dtype)


def sharded_rms_norm(x: Array, scale: Array, ctx: ShardCtx,
                     eps: float = 1e-6) -> Array:
    """RMS norm over a feature dim that is SHARDED over ``model`` (used by
    the Mamba2 gated norm whose d_inner channels are tensor-parallel):
    the mean-square reduces globally via one scalar-ish psum."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    local_dim = x.shape[-1]
    ssq = ctx.psum_model(jnp.sum(x * x, axis=-1, keepdims=True))
    var = ssq / (local_dim * ctx.tp)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float, style: str) -> Array:
    """Inverse frequencies.  ``style='half'`` (chatglm 2d-RoPE) rotates only
    the first half of each head dim; ``'full'`` rotates all of it."""
    rot = head_dim if style == "full" else head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, jnp.float32) / rot))


def apply_rope(x: Array, positions: Array, theta: float, style: str) -> Array:
    """x: (..., S, H, hd) or (..., H, hd) with matching positions (..., S)/().

    Rotates pairs (x[2i], x[2i+1]) within the rotary span; the non-rotary
    tail (half-style) passes through unchanged."""
    if style == "none":
        return x
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta, style)          # (rot/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    # broadcast over the head axis: x is (..., S, H, hd) -> angles (..., S, 1, rot/2)
    angles = angles[..., None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    rot = 2 * freqs.shape[0]
    xr, tail = x[..., :rot], x[..., rot:]
    x1 = xr[..., 0::2].astype(jnp.float32)
    x2 = xr[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, tail], axis=-1) if tail.shape[-1] else out


# ---------------------------------------------------------------------------
# tensor-parallel linear / embedding
# ---------------------------------------------------------------------------


def col_linear(x: Array, w: Array, b: Array | None = None) -> Array:
    """Column-parallel: w is a LOCAL (d_in, d_out/tp) slice; output is the
    local feature slice — no collective."""
    y = jnp.einsum("...i,io->...o", x, w)
    if b is not None:
        y = y + b
    return y


def row_linear(x_local: Array, w: Array, ctx: ShardCtx,
               b: Array | None = None) -> Array:
    """Row-parallel: x_local is this shard's input-feature slice, w its
    (d_in/tp, d_out) slice; the partial products are psum'ed over model."""
    y = ctx.psum_model(jnp.einsum("...i,io->...o", x_local, w))
    if b is not None:
        y = y + b
    return y


def vocab_embed(tokens: Array, table: Array, ctx: ShardCtx,
                vocab_size: int) -> Array:
    """Vocab-sharded embedding lookup: table is a LOCAL (V/tp, d) slice;
    out-of-range ids contribute zero and the psum assembles the row."""
    local_v = table.shape[0]
    offset = ctx.model_index() * local_v
    local_ids = tokens - offset
    ok = (local_ids >= 0) & (local_ids < local_v)
    rows = jnp.take(table, jnp.clip(local_ids, 0, local_v - 1), axis=0)
    rows = jnp.where(ok[..., None], rows, jnp.zeros_like(rows))
    out = ctx.psum_model(rows)
    del vocab_size
    return out


def vocab_parallel_logits(x: Array, head: Array) -> Array:
    """LM head: head is a LOCAL (d, V/tp) slice -> local logits slice."""
    return jnp.einsum("...d,dv->...v", x, head)


def vocab_parallel_xent(local_logits: Array, labels: Array,
                        ctx: ShardCtx) -> Array:
    """Cross-entropy over a vocab-sharded logit tensor (..., V/tp).

    Uses the standard 3-collective scheme: pmax for the global max, psum for
    the partition function, psum for the label logit."""
    local_v = local_logits.shape[-1]
    offset = ctx.model_index() * local_v
    logits = local_logits.astype(jnp.float32)

    # max-shift is gradient-neutral (d logsumexp/dm == 0); stop_gradient goes
    # INSIDE the pmax because pmax itself has no differentiation rule
    gmax = ctx.pmax_model(jax.lax.stop_gradient(jnp.max(logits, axis=-1)))
    sumexp = ctx.psum_model(jnp.sum(jnp.exp(logits - gmax[..., None]), axis=-1))

    local_label = labels - offset
    ok = (local_label >= 0) & (local_label < local_v)
    picked = jnp.take_along_axis(
        logits, jnp.clip(local_label, 0, local_v - 1)[..., None], axis=-1
    )[..., 0]
    label_logit = ctx.psum_model(jnp.where(ok, picked, 0.0))

    return jnp.log(sumexp) + gmax - label_logit


def vocab_parallel_sample(local_logits: Array, ctx: ShardCtx, rng: Array,
                          temperature: float = 1.0) -> Array:
    """Temperature sampling over a vocab-sharded logit tensor via the
    Gumbel-max trick: argmax(logits/T + G) needs only the existing
    pmax/pmin combine — no logit gather.  The key must be IDENTICAL on all
    model shards; per-shard noise comes from folding in the vocab offset."""
    local_v = local_logits.shape[-1]
    offset = ctx.model_index() * local_v
    shard_key = jax.random.fold_in(rng, offset)
    g = jax.random.gumbel(shard_key, local_logits.shape, jnp.float32)
    return vocab_parallel_argmax(
        local_logits.astype(jnp.float32) / max(temperature, 1e-6) + g, ctx)


def vocab_parallel_argmax(local_logits: Array, ctx: ShardCtx) -> Array:
    """Greedy next-token id over a vocab-sharded logit tensor (..., V/tp)."""
    local_v = local_logits.shape[-1]
    offset = ctx.model_index() * local_v
    logits = local_logits.astype(jnp.float32)
    lmax = jnp.max(logits, axis=-1)
    larg = jnp.argmax(logits, axis=-1).astype(jnp.int32) + offset
    gmax = ctx.pmax_model(lmax)
    # the shard holding the global max reports its index; others report INF
    cand = jnp.where(lmax >= gmax, larg, jnp.iinfo(jnp.int32).max)
    if ctx.model_axis is None:
        return cand
    return jax.lax.pmin(cand, ctx.model_axis)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_params(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype),   # column-parallel
        "up": dense_init(k2, d_model, d_ff, dtype),     # column-parallel
        "down": dense_init(k3, d_ff, d_model, dtype),   # row-parallel
    }


def mlp(params: dict, x: Array, ctx: ShardCtx) -> Array:
    h = jax.nn.silu(col_linear(x, params["gate"])) * col_linear(x, params["up"])
    return row_linear(h, params["down"], ctx)


def causal_conv1d(x: Array, w: Array, b: Array | None = None) -> Array:
    """Depthwise causal conv over the sequence axis.  x: (B, S, C),
    w: (K, C) depthwise taps.  Used by Mamba2 and RG-LRU blocks."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # K is tiny (4) — unrolled taps keep HLO simple
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    if b is not None:
        out = out + b
    return out


def causal_conv1d_step(x_t: Array, conv_state: Array, w: Array,
                       b: Array | None = None) -> tuple[Array, Array]:
    """Single decode step.  x_t: (B, C); conv_state: (B, K-1, C) past inputs.
    Returns (y_t, new_state)."""
    k = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window, w)
    if b is not None:
        y = y + b
    return y, window[:, 1:k, :]
