"""Top-level model: embedding → (prefix layers ∥ scanned pattern blocks) →
norm → vocab-parallel head, with train / prefill / decode entry points.

One class serves all 10 assigned architectures; the LayerSpec pattern in the
config decides which mixers run.  Params layout:

    {"embed": (V, d), "prefix": (layer_dict, ...),
     "blocks": (stacked_layer_dict_per_pattern_position, ...),
     "final_norm": (d,), "head": (d, V) [absent when tied],
     "encoder": {...} [audio], "mtp": {...} [deepseek]}

Stacked leaves (leading repeat dim) live under "blocks" — the partitioning
rules in `repro.sharding.partition` key off that path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import blocks as blk
from repro.models.layers import (
    embed_init,
    dense_init,
    rms_norm,
    rms_norm_params,
    vocab_embed,
    vocab_parallel_argmax,
    vocab_parallel_logits,
    vocab_parallel_xent,
)
from repro.sharding.ctx import ShardCtx, unsharded
from repro.sharding.partition import fsdp_axes, fsdp_gather

Array = jax.Array
PyTree = Any


def _scan_unroll(repeats: int) -> int:
    """Fully unroll tiny stacks (<= 2 repeats).  This keeps production HLO
    O(pattern) via scan while letting the dry-run's 1-/2-repeat variants
    produce EXACT per-layer cost analysis (XLA's HloCostAnalysis counts a
    while-loop body once, so scanned modules under-report flops/bytes by
    ~the trip count — see EXPERIMENTS.md §Roofline methodology)."""
    return repeats if repeats <= 2 else 1


@functools.lru_cache(maxsize=None)
def _fsdp_axes_cached(cfg: ModelConfig, dp: int, tp: int) -> Any:
    """Per-leaf FSDP gather axes, computed once per (cfg, mesh) on global
    abstract shapes (hashable ModelConfig makes this cacheable)."""
    from repro.sharding.partition import replicate_set

    abstract = Model(cfg).abstract_params()
    return fsdp_axes(abstract, dp=dp, tp=tp, fsdp=True,
                     replicate=replicate_set(cfg, tp))


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------

    def init(self, key) -> PyTree:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        k_embed, k_prefix, k_blocks, k_head, k_enc, k_mtp = jax.random.split(key, 6)
        cross = cfg.is_encdec

        params: dict = {
            "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
            "final_norm": rms_norm_params(cfg.d_model, dtype),
        }

        params["prefix"] = tuple(
            blk.layer_params(cfg, spec, k, dtype, cross)
            for spec, k in zip(cfg.prefix,
                               jax.random.split(k_prefix, max(len(cfg.prefix), 1)))
        )

        def one_repeat(k):
            ks = jax.random.split(k, len(cfg.pattern))
            return tuple(blk.layer_params(cfg, spec, kk, dtype, cross)
                         for spec, kk in zip(cfg.pattern, ks))

        params["blocks"] = jax.vmap(one_repeat)(
            jax.random.split(k_blocks, cfg.num_repeats))

        if not cfg.tie_embeddings:
            params["head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size,
                                        dtype)
        if cfg.encoder is not None:
            e = cfg.encoder
            enc_cfg = dataclasses.replace(
                cfg, d_model=e.d_model, num_heads=e.num_heads,
                num_kv_heads=e.num_heads, d_ff=e.d_ff, head_dim=0,
                qk_norm=False, qkv_bias=False)
            spec = LayerSpec("attn", "dense")

            def one_enc(k):
                return blk.layer_params(enc_cfg, spec, k, dtype)

            params["encoder"] = {
                "blocks": jax.vmap(one_enc)(
                    jax.random.split(k_enc, e.num_layers)),
                "final_norm": rms_norm_params(e.d_model, dtype),
            }
        if cfg.mtp_depth > 0:
            km1, km2 = jax.random.split(k_mtp)
            params["mtp"] = {
                "mtp_proj": dense_init(km1, 2 * cfg.d_model, cfg.d_model, dtype),
                "layer": blk.layer_params(
                    cfg, LayerSpec(cfg.pattern[0].mixer, "dense"), km2, dtype),
                "final_norm": rms_norm_params(cfg.d_model, dtype),
            }
        return params

    def abstract_params(self) -> PyTree:
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # ------------------------------------------------------------------
    # shared hidden pass (full sequence)
    # ------------------------------------------------------------------

    def _embed_inputs(self, params: PyTree, batch: dict, ctx: ShardCtx):
        """Token (+ modality) embedding.  Returns (x, positions, n_prefix_tok)."""
        cfg = self.cfg
        x = vocab_embed(batch["tokens"], params["embed"], ctx, cfg.vocab_size)
        x = x.astype(jnp.dtype(cfg.activ_dtype))
        n_extra = 0
        if cfg.family == "vlm" and "vision" in batch:
            vis = batch["vision"].astype(x.dtype)       # (B, nv, d) stub
            x = jnp.concatenate([vis, x], axis=1)
            n_extra = vis.shape[1]
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        return x, positions, n_extra

    def _encode(self, params: PyTree, source: Array, ctx: ShardCtx) -> Array:
        """Audio encoder over stubbed frame embeddings (B, T, d_enc)."""
        cfg = self.cfg
        e = cfg.encoder
        enc_cfg = dataclasses.replace(
            cfg, d_model=e.d_model, num_heads=e.num_heads,
            num_kv_heads=e.num_heads, d_ff=e.d_ff, head_dim=0,
            qk_norm=False, qkv_bias=False)
        spec = LayerSpec("attn", "dense")
        x = source.astype(jnp.dtype(cfg.activ_dtype))
        t = x.shape[1]
        # bidirectional: every query sees every kv
        positions = jnp.full((t,), t, jnp.int32)

        def body(carry, p):
            h, _, _ = blk.layer_seq(enc_cfg, spec, p, carry,
                                    positions, ctx, None)
            return h, None

        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"],
                            unroll=_scan_unroll(e.num_layers))
        return rms_norm(x, params["encoder"]["final_norm"])

    def _fsdp_active(self, ctx: ShardCtx) -> bool:
        return self.cfg.fsdp and ctx.data_axis is not None and ctx.dp > 1

    def _blk_axes(self, ctx: ShardCtx):
        if not self._fsdp_active(ctx):
            return None
        return _fsdp_axes_cached(self.cfg, ctx.dp, ctx.tp)["blocks"]

    def _gather_fsdp(self, params: PyTree, ctx: ShardCtx):
        """All-gather FSDP-sharded NON-block params eagerly; return the
        per-repeat gather axes for the scanned blocks (gathered JIT inside
        the scan body so only one repeat's weights are resident).

        NOT idempotent — callers must gather exactly once per step; entry
        points (loss / prefill / decode_step) gather and pass
        ``gathered=True`` down to hidden_sequence."""
        if not self._fsdp_active(ctx):
            return params, None
        axes = _fsdp_axes_cached(self.cfg, ctx.dp, ctx.tp)
        rest = {k: v for k, v in params.items() if k != "blocks"}
        rest_axes = {k: axes[k] for k in rest}
        gathered = fsdp_gather(rest, rest_axes, ctx)
        gathered["blocks"] = params["blocks"]
        return gathered, axes["blocks"]

    def hidden_sequence(self, params: PyTree, batch: dict, ctx: ShardCtx,
                        caches: PyTree | None = None, *,
                        remat: bool = False, gathered: bool = False):
        """Returns (h (B,S,d), new_caches, aux, enc_out, n_extra)."""
        cfg = self.cfg
        if gathered:
            blk_axes = self._blk_axes(ctx)
        else:
            params, blk_axes = self._gather_fsdp(params, ctx)
        x, positions, n_extra = self._embed_inputs(params, batch, ctx)
        enc_out = None
        if cfg.is_encdec:
            enc_out = self._encode(params, batch["source"], ctx)

        aux = jnp.zeros((), jnp.float32)
        new_prefix = []
        for i, spec in enumerate(cfg.prefix):
            c = None if caches is None else caches["prefix"][i]
            x, c, a = blk.layer_seq(cfg, spec, params["prefix"][i], x,
                                    positions, ctx, c, enc_out)
            new_prefix.append(c)
            aux = aux + a

        pattern = cfg.pattern

        if caches is None:
            def body(carry, p):
                h, acc = carry
                if blk_axes is not None:
                    p = fsdp_gather(p, blk_axes, ctx)
                for j, spec in enumerate(pattern):
                    h, _, a = blk.layer_seq(cfg, spec, p[j], h, positions,
                                            ctx, None, enc_out)
                    acc = acc + a
                return (h, acc), None

            if remat:
                body = jax.checkpoint(body)
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"],
                                       unroll=_scan_unroll(cfg.num_repeats))
            new_blocks = None
        else:
            def body(carry, inp):
                h, acc = carry
                p, cs = inp
                if blk_axes is not None:
                    p = fsdp_gather(p, blk_axes, ctx)
                ncs = []
                for j, spec in enumerate(pattern):
                    h, nc, a = blk.layer_seq(cfg, spec, p[j], h, positions,
                                             ctx, cs[j], enc_out)
                    ncs.append(nc)
                    acc = acc + a
                return (h, acc), tuple(ncs)

            (x, aux), new_blocks = jax.lax.scan(
                body, (x, aux), (params["blocks"], caches["blocks"]),
                unroll=_scan_unroll(cfg.num_repeats))

        x = rms_norm(x, params["final_norm"])
        new_caches = None
        if caches is not None:
            new_caches = {"prefix": tuple(new_prefix), "blocks": new_blocks}
        return x, new_caches, aux, enc_out, n_extra

    # ------------------------------------------------------------------
    # logits / loss
    # ------------------------------------------------------------------

    def _local_logits(self, params: PyTree, h: Array) -> Array:
        if self.cfg.tie_embeddings:
            return jnp.einsum("...d,vd->...v", h, params["embed"])
        return vocab_parallel_logits(h, params["head"])

    def loss(self, params: PyTree, batch: dict, ctx: ShardCtx | None = None,
             *, remat: bool = True):
        """Mean next-token cross-entropy over the LOCAL batch shard
        (+ MoE aux + MTP).  Returns (loss, metrics)."""
        ctx = ctx or unsharded()
        cfg = self.cfg
        params, _ = self._gather_fsdp(params, ctx)  # head/mtp need full leaves
        h, _, aux, _, n_extra = self.hidden_sequence(params, batch, ctx,
                                                     remat=remat,
                                                     gathered=True)
        if n_extra:
            h = h[:, n_extra:, :]
        labels = batch["labels"]
        lg = self._local_logits(params, h)
        xe = vocab_parallel_xent(lg, jnp.maximum(labels, 0), ctx)
        mask = (labels >= 0).astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        ce = jnp.sum(xe * mask) / denom
        total = ce + aux

        if cfg.mtp_depth > 0:
            total = total + 0.1 * self._mtp_loss(params, batch, h, ctx)

        return total, {"ce": ce, "aux": aux}

    def _mtp_loss(self, params: PyTree, batch: dict, h: Array,
                  ctx: ShardCtx) -> Array:
        """DeepSeek MTP: one extra block predicts token t+2 from
        (h_t, embed(token_{t+1}))."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        emb_next = vocab_embed(tokens[:, 1:], params["embed"], ctx,
                               cfg.vocab_size).astype(h.dtype)
        inp = jnp.concatenate([h[:, :-1, :], emb_next], axis=-1)
        x = jnp.einsum("...i,io->...o", inp, params["mtp"]["mtp_proj"])
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        spec = LayerSpec(cfg.pattern[0].mixer, "dense")
        x, _, _ = blk.layer_seq(cfg, spec, params["mtp"]["layer"], x,
                                positions, ctx, None)
        x = rms_norm(x, params["mtp"]["final_norm"])
        lg = self._local_logits(params, x)
        lbl = labels[:, 1:]
        xe = vocab_parallel_xent(lg, jnp.maximum(lbl, 0), ctx)
        mask = (lbl >= 0).astype(jnp.float32)
        return jnp.sum(xe * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------

    def init_caches(self, batch_size: int, seq_len: int,
                    ctx: ShardCtx | None = None) -> PyTree:
        ctx = ctx or unsharded()
        cfg = self.cfg
        dtype = jnp.dtype(cfg.activ_dtype)
        prefix = tuple(
            blk.init_layer_cache(cfg, spec, batch_size, seq_len, ctx, dtype)
            for spec in cfg.prefix)

        def one(_):
            return tuple(
                blk.init_layer_cache(cfg, spec, batch_size, seq_len, ctx, dtype)
                for spec in cfg.pattern)

        stacked = jax.vmap(one)(jnp.arange(cfg.num_repeats))
        return {"prefix": prefix, "blocks": stacked}

    def prefill(self, params: PyTree, batch: dict, seq_len: int,
                ctx: ShardCtx | None = None):
        """Process the full prompt; returns (caches, next_token, enc_out)."""
        ctx = ctx or unsharded()
        params, _ = self._gather_fsdp(params, ctx)
        caches = self.init_caches(batch["tokens"].shape[0], seq_len, ctx)
        h, caches, _, enc_out, n_extra = self.hidden_sequence(
            params, batch, ctx, caches, gathered=True)
        last = h[:, -1, :]
        nxt = vocab_parallel_argmax(self._local_logits(params, last), ctx)
        return caches, nxt, enc_out

    def decode_step(self, params: PyTree, token: Array, pos: Array,
                    caches: PyTree, ctx: ShardCtx | None = None,
                    enc_out: Array | None = None):
        """One greedy decode step.  token: (B,) int32; pos: scalar int32.

        Returns (next_token (B,), new_caches)."""
        ctx = ctx or unsharded()
        cfg = self.cfg
        params, blk_axes = self._gather_fsdp(params, ctx)
        x1 = vocab_embed(token[:, None], params["embed"], ctx,
                         cfg.vocab_size)[:, 0, :]
        x1 = x1.astype(jnp.dtype(cfg.activ_dtype))

        new_prefix = []
        for i, spec in enumerate(cfg.prefix):
            x1, c = blk.layer_decode(cfg, spec, params["prefix"][i], x1, pos,
                                     caches["prefix"][i], ctx, enc_out)
            new_prefix.append(c)

        pattern = cfg.pattern

        def body(carry, inp):
            h1 = carry
            p, cs = inp
            if blk_axes is not None:
                p = fsdp_gather(p, blk_axes, ctx)
            ncs = []
            for j, spec in enumerate(pattern):
                h1, nc = blk.layer_decode(cfg, spec, p[j], h1, pos, cs[j],
                                          ctx, enc_out)
                ncs.append(nc)
            return h1, tuple(ncs)

        x1, new_blocks = jax.lax.scan(body, x1,
                                      (params["blocks"], caches["blocks"]),
                                      unroll=_scan_unroll(cfg.num_repeats))
        x1 = rms_norm(x1, params["final_norm"])
        nxt = vocab_parallel_argmax(self._local_logits(params, x1), ctx)
        return nxt, {"prefix": tuple(new_prefix), "blocks": new_blocks}


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
