"""Mixture-of-Experts layer (Mixtral / DeepSeek-V3 style).

Design (TPU, manual TP inside shard_map):

* Activations are replicated across the ``model`` axis (the framework's
  layer-level convention), so routing is computed locally on every shard.
* Expert FFN weights are sharded over ``model`` on the **d_ff dimension**
  ("expert tensor parallelism"): every shard holds a 1/tp slice of ALL
  experts and the combine rides the row-parallel psum that the dense MLP
  already pays.  No all-to-all is needed because tokens never move.
  (An all_to_all expert-parallel variant is an explicit §Perf candidate —
  see EXPERIMENTS.md.)
* Dispatch is **sort-based with capacity** (MegaBlocks-style, not the
  GShard one-hot einsum): tokens are bucketed to (expert, slot) via a
  stable argsort of the routed expert ids, giving O(T·k log T·k) index work
  and exactly ``E * C`` rows of expert GEMM — no T x E x C einsum blow-up.
* Router: softmax top-k with renormalization (Mixtral) or
  sigmoid+normalize (DeepSeek-V3 uses sigmoid scoring); we use softmax
  for both, plus the standard load-balance auxiliary loss.

Shared ("always-on") experts — DeepSeek's 1 shared expert — are a plain
dense MLP added to the routed output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import perf
from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, mlp, mlp_params
from repro.sharding.ctx import ShardCtx

Array = jax.Array


def moe_params(cfg: ModelConfig, key, dtype) -> dict:
    m = cfg.moe
    d = cfg.d_model
    fe = m.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    e = m.num_experts
    scale = (2.0 / (d + fe)) ** 0.5
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),  # replicated, f32
        # experts: (E, d, fe) column / (E, fe, d) row — fe sharded over model
        "w_gate": (jax.random.normal(ks[1], (e, d, fe), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, fe), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, fe, d), jnp.float32) * scale).astype(dtype),
    }
    if m.num_shared:
        p["shared"] = mlp_params(ks[4], d, fe * m.num_shared, dtype)
    return p


def _capacity(tokens: int, num_experts: int, top_k: int, factor: float) -> int:
    cap = int(tokens * top_k * factor / num_experts) + 1
    # round up to a lane-friendly multiple of 8 (128 when large)
    mult = 128 if cap >= 512 else 8
    return ((cap + mult - 1) // mult) * mult


def moe_mlp(params: dict, cfg: ModelConfig, x: Array, ctx: ShardCtx):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k
    xf = x.reshape(t, d)

    # ---- routing (replicated compute; f32 for stable softmax) -------------
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                    # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # load-balance aux loss (Shazeer/Switch): E * mean(frac_tokens*frac_prob)
    counts = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    frac_tok = counts / (t * k)
    frac_prob = jnp.mean(probs, axis=0)
    aux = m.aux_loss_weight * e * jnp.sum(frac_tok * frac_prob)

    flat_e = top_i.reshape(-1)                                # (T*k,)
    flat_w = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)

    if perf.enabled("sparse_moe_gather") and t * k < e:
        # §Perf `sparse_moe_gather`: low-occupancy decode — gather only the
        # routed experts' weight slices (T*k of E) instead of streaming all
        # E experts through the dense GEMM.  Weight bytes: E*3*d*fe/tp ->
        # T*k*3*d*fe/tp per step.
        xi = xf[flat_tok]                                     # (T*k, d)
        w_g = jnp.take(params["w_gate"], flat_e, axis=0)      # (T*k, d, fe)
        w_u = jnp.take(params["w_up"], flat_e, axis=0)
        w_d = jnp.take(params["w_down"], flat_e, axis=0)
        hh = jax.nn.silu(jnp.einsum("td,tdf->tf", xi, w_g))
        hh = hh * jnp.einsum("td,tdf->tf", xi, w_u)
        yy = ctx.psum_model(jnp.einsum("tf,tfd->td", hh, w_d))
        out = jnp.zeros((t, d), yy.dtype).at[flat_tok].add(
            yy * flat_w[:, None].astype(yy.dtype))
        if m.num_shared:
            out = out + mlp(params["shared"], xf, ctx)
        return out.reshape(b, s, d).astype(x.dtype), aux

    # ---- sort-based dispatch with capacity --------------------------------
    cap = _capacity(t, e, k, m.capacity_factor)

    order = jnp.argsort(flat_e, stable=True)                  # group by expert
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    w_sorted = flat_w[order]
    counts_i = jnp.bincount(flat_e, length=e)
    seg_start = jnp.cumsum(counts_i) - counts_i               # (E,)
    slot = jnp.arange(t * k) - seg_start[e_sorted]            # rank in expert
    keep = slot < cap                                         # capacity drop

    # gather tokens into the (E*C, d) expert buffer
    buf_idx = e_sorted * cap + jnp.clip(slot, 0, cap - 1)
    buffer = jnp.zeros((e * cap + 1, d), x.dtype)             # +1 = trash slot
    src = jnp.where(keep, buf_idx, e * cap)
    buffer = buffer.at[src].add(xf[tok_sorted].astype(x.dtype))
    buffer = buffer[:-1].reshape(e, cap, d)

    # ---- expert GEMMs (fe sharded over model; psum on the way out) --------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buffer, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buffer, params["w_up"])
    y_part = jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    if perf.enabled("fused_moe_psum"):
        # §Perf `fused_moe_psum`: gather/scatter are linear, so commute them
        # with the psum and merge the shared-expert partial — ONE (T, d)
        # psum per layer instead of (E*cap, d) + (T, d).
        y_buf = y_part.reshape(e * cap, d)
        routed = jnp.take(y_buf, buf_idx, axis=0)
        routed = routed * (w_sorted * keep)[:, None].astype(routed.dtype)
        out = jnp.zeros((t, d), routed.dtype).at[tok_sorted].add(routed)
        if m.num_shared:
            sh = params["shared"]
            hs = jax.nn.silu(jnp.einsum("td,df->tf", xf, sh["gate"]))
            hs = hs * jnp.einsum("td,df->tf", xf, sh["up"])
            out = out + jnp.einsum("tf,fd->td", hs, sh["down"])
        out = ctx.psum_model(out)
        return out.reshape(b, s, d).astype(x.dtype), aux

    y_buf = ctx.psum_model(y_part).reshape(e * cap, d)

    # ---- combine back to tokens -------------------------------------------
    routed = jnp.take(y_buf, buf_idx, axis=0)                 # (T*k, d)
    routed = routed * (w_sorted * keep)[:, None].astype(routed.dtype)
    out = jnp.zeros((t, d), routed.dtype).at[tok_sorted].add(routed)

    if m.num_shared:
        out = out + mlp(params["shared"], xf, ctx)
    return out.reshape(b, s, d).astype(x.dtype), aux
