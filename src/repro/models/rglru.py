"""RecurrentGemma recurrent block: causal conv + RG-LRU gated linear
recurrence [arXiv:2402.19427].

RG-LRU per channel:
    r_t = sigmoid(W_a x_t)                    (recurrence gate)
    i_t = sigmoid(W_x x_t)                    (input gate)
    log a_t = -c * softplus(Λ) * r_t          (Λ learnable, c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill uses `lax.associative_scan` over the sequence (the
recurrence h_t = a_t h_{t-1} + b_t is associative) — O(S log S) work on
O(log S) depth; decode is the O(1) single step.

TP: lru_width channels are sharded over ``model`` (gates, Λ, conv taps all
live per-channel); the block's linear-in / linear-out are column / row
parallel respectively.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    causal_conv1d,
    causal_conv1d_step,
    col_linear,
    dense_init,
    row_linear,
)
from repro.sharding.ctx import ShardCtx

Array = jax.Array


class RGLRUCache(NamedTuple):
    h: Array         # (B, W_local) recurrent state
    conv: Array      # (B, K-1, W_local) conv tail


def rglru_params(cfg: ModelConfig, key, dtype) -> dict:
    r = cfg.rglru
    d = cfg.d_model
    w = r.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        # column-parallel branch projections (sharded on lru width)
        "w_in": dense_init(ks[0], d, w, dtype),
        "w_gate_branch": dense_init(ks[1], d, w, dtype),
        "conv": (jax.random.normal(ks[2], (r.d_conv, w), jnp.float32)
                 * 0.1).astype(dtype),
        # per-channel RG-LRU gates (diagonal W_a / W_x as in the paper's
        # block-diagonal approximation; full dense gates are the variant)
        "w_a": dense_init(ks[3], d, w, dtype),
        "w_x": dense_init(ks[4], d, w, dtype),
        "lam": jnp.full((w,), 0.5, jnp.float32),   # Λ (softplus-parameterized)
        # row-parallel out
        "w_out": dense_init(ks[5], w, d, dtype),
    }


def _gates(params: dict, x: Array, u: Array, c: float):
    """Compute (log_a, b) for the recurrence h = a*h + b.  x: raw block
    input (for the gates); u: conv'd branch signal."""
    r = jax.nn.sigmoid(col_linear(x, params["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(col_linear(x, params["w_x"]).astype(jnp.float32))
    log_a = -c * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * u.astype(jnp.float32)
    return a, b


def rglru_sequence(params: dict, cfg: ModelConfig, x: Array, ctx: ShardCtx,
                   want_cache: bool):
    """Full-sequence recurrent block.  x: (B, S, d)."""
    r = cfg.rglru
    u_raw = col_linear(x, params["w_in"])                 # (B,S,Wl)
    gate = jax.nn.gelu(col_linear(x, params["w_gate_branch"]))
    u = causal_conv1d(u_raw, params["conv"])
    a, b = _gates(params, x, u, r.c)

    # associative scan over the sequence: (a2,b2)∘(a1,b1) = (a1a2, a2 b1 + b2)
    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(x.dtype) * gate)
    out = row_linear(y, params["w_out"], ctx)

    cache = None
    if want_cache:
        k = r.d_conv - 1
        cache = RGLRUCache(h=h[:, -1, :].astype(x.dtype),
                           conv=u_raw[:, -k:, :])
    return out, cache


def init_rglru_cache(batch: int, cfg: ModelConfig, ctx: ShardCtx,
                     dtype) -> RGLRUCache:
    w = (cfg.rglru.lru_width or cfg.d_model) // ctx.tp
    k = cfg.rglru.d_conv - 1
    return RGLRUCache(h=jnp.zeros((batch, w), dtype),
                      conv=jnp.zeros((batch, k, w), dtype))


def rglru_decode(params: dict, cfg: ModelConfig, x1: Array,
                 cache: RGLRUCache, ctx: ShardCtx):
    """Single-token step.  x1: (B, d)."""
    r = cfg.rglru
    u_raw = col_linear(x1, params["w_in"])
    gate = jax.nn.gelu(col_linear(x1, params["w_gate_branch"]))
    u, conv = causal_conv1d_step(u_raw, cache.conv, params["conv"])
    a, b = _gates(params, x1, u, r.c)
    h = a * cache.h.astype(jnp.float32) + b
    y = h.astype(x1.dtype) * gate
    out = row_linear(y, params["w_out"], ctx)
    return out, RGLRUCache(h=h.astype(x1.dtype), conv=conv)