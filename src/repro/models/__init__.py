"""repro.models — the 10-architecture model zoo (manual-TP, shard_map-ready)."""

from repro.models.model import Model, build_model

__all__ = ["Model", "build_model"]
