"""Attention: GQA (full + sliding-window) and DeepSeek-style MLA, with
flash-style streaming softmax for train/prefill and a **sequence-parallel
decode path** (the KV cache is sharded over the ``model`` axis on the
sequence dimension; partial (out, lse) pairs combine with one tiny psum).

Sequence-parallel decode is the TPU adaptation that makes the ``long_500k``
shape feasible: a 524288-token cache never lives on one chip, and the scheme
is uniform in ``num_kv_heads`` (no head-divisibility constraint).

Layout conventions:
  q:    (B, S, Hl, hd)      Hl = local (model-sharded) query heads
  k/v:  (B, S, KVl, hd)     KVl = kv heads this shard computes with
  caches (decode): (B, S_loc, KV, hd) — FULL kv heads, LOCAL seq slice.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    apply_rope,
    col_linear,
    dense_init,
    rms_norm,
    rms_norm_params,
    row_linear,
)
from repro.sharding.ctx import ShardCtx

Array = jax.Array
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def attn_params(cfg: ModelConfig, key, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype),       # column (heads)
        "wk": dense_init(ks[1], d, kv * hd, dtype),      # replicated or column
        "wv": dense_init(ks[2], d, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),       # row (+psum)
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rms_norm_params(hd, dtype)
        p["k_norm"] = rms_norm_params(hd, dtype)
    return p


def mla_params(cfg: ModelConfig, key, dtype) -> dict:
    m, d, h = cfg.mla, cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 6)
    return {
        "w_dq": dense_init(ks[0], d, m.q_lora_rank, dtype),          # repl.
        "q_norm": rms_norm_params(m.q_lora_rank, dtype),
        "w_uq": dense_init(ks[1], m.q_lora_rank,
                           h * (m.nope_head_dim + m.rope_head_dim), dtype),
        "w_dkv": dense_init(ks[2], d, m.kv_lora_rank + m.rope_head_dim, dtype),
        "kv_norm": rms_norm_params(m.kv_lora_rank, dtype),
        "w_uk": dense_init(ks[3], m.kv_lora_rank, h * m.nope_head_dim, dtype),
        "w_uv": dense_init(ks[4], m.kv_lora_rank, h * m.v_head_dim, dtype),
        "wo": dense_init(ks[5], h * m.v_head_dim, d, dtype),         # row
    }


# ---------------------------------------------------------------------------
# flash-style full attention (train / prefill, causal)
# ---------------------------------------------------------------------------


def _softcap(s: Array, cap: float) -> Array:
    return cap * jnp.tanh(s / cap) if cap > 0.0 else s


def flash_attention(q: Array, k: Array, v: Array, q_pos: Array, kv_pos: Array,
                    *, window: int = 0, softcap: float = 0.0,
                    block: int = 1024) -> Array:
    """Streaming-softmax causal attention over KV blocks (O(S·block) memory).

    q: (B,Sq,Hl,hd); k/v: (B,Skv,KVl,hd) with Hl % KVl == 0.
    ``window > 0`` additionally masks kv older than ``window`` positions."""
    b, sq, hl, hd = q.shape
    skv, kvl = k.shape[1], k.shape[2]
    g = hl // kvl
    scale = hd ** -0.5
    qr = (q * scale).reshape(b, sq, kvl, g, hd).astype(jnp.float32)

    block = min(block, skv)
    nb = math.ceil(skv / block)
    pad = nb * block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    kb = k.reshape(b, nb, block, kvl, hd).swapaxes(0, 1)
    vb = v.reshape(b, nb, block, kvl, hd).swapaxes(0, 1)
    pb = kv_pos.reshape(nb, block)

    def body(carry, blk):
        m, l, acc = carry
        kj, vj, pj = blk
        s = jnp.einsum("bqkgd,bjkd->bqkgj", qr, kj.astype(jnp.float32))
        s = _softcap(s, softcap)
        ok = pj[None, None, None, None, :] <= q_pos[None, :, None, None, None]
        if window:
            ok &= (q_pos[None, :, None, None, None]
                   - pj[None, None, None, None, :]) < window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgj,bjkd->bqkgd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kvl, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kvl, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kvl, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, hl, hd).astype(q.dtype)


def local_attention(q: Array, k: Array, v: Array, positions: Array,
                    window: int, softcap: float = 0.0) -> Array:
    """Chunked sliding-window attention: O(S · 2W) FLOPs instead of O(S²).

    Each length-W chunk attends to itself + the previous chunk under the
    causal ∧ (q_pos - kv_pos < W) mask — exactly SWA when chunk == window."""
    b, s, hl, hd = q.shape
    kvl = k.shape[2]
    g = hl // kvl
    w = min(window, s)
    nc = math.ceil(s / w)
    pad = nc * w - s

    def chunk(x, fill=0.0):
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2),
                    constant_values=fill)
        return x.reshape((b, nc, w) + x.shape[2:])

    big = jnp.iinfo(jnp.int32).max
    qp = jnp.pad(positions, (0, pad), constant_values=big - 1).reshape(nc, w)
    kp = jnp.pad(positions, (0, pad), constant_values=big).reshape(nc, w)
    qc = chunk(q).reshape(b, nc, w, kvl, g, hd)
    kc, vc = chunk(k), chunk(v)

    def prev(x, fill=0.0):
        shifted = jnp.roll(x, 1, axis=1)
        return shifted.at[:, 0].set(fill) if x.ndim > 2 else shifted

    kcat = jnp.concatenate([prev(kc), kc], axis=2)        # (b, nc, 2w, kvl, hd)
    vcat = jnp.concatenate([prev(vc), vc], axis=2)
    kpcat = jnp.concatenate(
        [jnp.roll(kp, 1, axis=0).at[0].set(big), kp], axis=1)  # (nc, 2w)

    scale = hd ** -0.5
    s_ = jnp.einsum("bcqkgd,bcjkd->bcqkgj",
                    (qc * scale).astype(jnp.float32), kcat.astype(jnp.float32))
    s_ = _softcap(s_, softcap)
    dq = qp[None, :, :, None, None, None]
    dk = kpcat[None, :, None, None, None, :]
    ok = (dk <= dq) & ((dq - dk) < window)
    s_ = jnp.where(ok, s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    p = jnp.where(jnp.any(ok, axis=-1, keepdims=True), p, 0.0)
    out = jnp.einsum("bcqkgj,bcjkd->bcqkgd", p, vcat.astype(jnp.float32))
    out = out.reshape(b, nc * w, hl, hd)[:, :s]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# sequence-parallel decode
# ---------------------------------------------------------------------------


class AttnCache(NamedTuple):
    """Per-layer decode cache, sequence-sharded over ``model``.

    k/v: (B, S_loc, KV, hd); pos: (S_loc,) global position stored in each
    slot (-1 = empty).  For SWA layers S_loc = window/tp (ring buffer)."""
    k: Array
    v: Array
    pos: Array


def init_attn_cache(batch: int, seq: int, kv_heads: int, hd: int,
                    ctx: ShardCtx, dtype) -> AttnCache:
    s_loc = seq // ctx.tp
    return AttnCache(
        k=jnp.zeros((batch, s_loc, kv_heads, hd), dtype),
        v=jnp.zeros((batch, s_loc, kv_heads, hd), dtype),
        pos=jnp.full((s_loc,), -1, jnp.int32),
    )


def _ring_sources(seq_len: int, s_loc: int, ctx: ShardCtx):
    """For each LOCAL cache slot, the prefill position that lands in it.

    The cache is a ring of period P = s_loc * tp over global positions;
    prefill positions are 0..seq_len-1, so the LAST position hitting global
    slot g is ``g + P * floor((seq_len - 1 - g) / P)`` (negative ⇒ empty).
    A gather formulation avoids duplicate-index scatter hazards."""
    start, _ = ctx.seq_shard_bounds(s_loc * ctx.tp)
    period = s_loc * ctx.tp
    gslots = start + jnp.arange(s_loc)
    reps = jnp.floor_divide(seq_len - 1 - gslots, period)
    src = gslots + period * reps
    valid = reps >= 0
    return jnp.clip(src, 0, seq_len - 1), valid, src


def cache_write_prefill(cache: AttnCache, k: Array, v: Array,
                        positions: Array, ctx: ShardCtx) -> AttnCache:
    """Store a full prefill's kv: this shard keeps its sequence slice
    (ring-mapped, so window caches smaller than the prefill also work)."""
    del positions  # prefill positions are 0..S-1 by construction
    s_loc = cache.k.shape[1]
    idx, valid, src = _ring_sources(k.shape[1], s_loc, ctx)
    k_new = jnp.where(valid[None, :, None, None], k[:, idx], cache.k)
    v_new = jnp.where(valid[None, :, None, None], v[:, idx], cache.v)
    pos_new = jnp.where(valid, src, cache.pos)
    return AttnCache(k_new.astype(cache.k.dtype),
                     v_new.astype(cache.v.dtype), pos_new.astype(jnp.int32))


def cache_write_token(cache: AttnCache, k1: Array, v1: Array,
                      pos: Array, ctx: ShardCtx) -> AttnCache:
    """Write one token's kv (B, KV, hd) at global position ``pos``."""
    s_loc = cache.k.shape[1]
    start, _ = ctx.seq_shard_bounds(s_loc * ctx.tp)
    slot = jnp.mod(pos, s_loc * ctx.tp)
    mine = (slot >= start) & (slot < start + s_loc)
    idx = jnp.clip(slot - start, 0, s_loc - 1)
    k_new = jax.lax.dynamic_update_slice(
        cache.k, k1[:, None].astype(cache.k.dtype), (0, idx, 0, 0))
    v_new = jax.lax.dynamic_update_slice(
        cache.v, v1[:, None].astype(cache.v.dtype), (0, idx, 0, 0))
    pos_new = jax.lax.dynamic_update_slice(
        cache.pos, pos[None].astype(jnp.int32), (idx,))
    return AttnCache(
        k=jnp.where(mine, k_new, cache.k),
        v=jnp.where(mine, v_new, cache.v),
        pos=jnp.where(mine, pos_new, cache.pos),
    )


def decode_attention(q: Array, cache: AttnCache, pos: Array, ctx: ShardCtx,
                     *, num_heads: int, window: int = 0,
                     softcap: float = 0.0) -> Array:
    """One-token attention over a sequence-sharded cache.

    q: (B, Hl, hd) — LOCAL query heads (Hl == num_heads when the head count
    doesn't divide tp and attention params are replicated); cache holds FULL
    kv heads for this shard's sequence slice.  Exact flash combine: each
    shard computes partial (max, sumexp, out); one psum/pmax pair merges."""
    from repro import perf

    b, hl, hd = q.shape
    kv = cache.k.shape[2]
    group = num_heads // kv
    ok = (cache.pos >= 0) & (cache.pos <= pos)
    if window:
        ok &= (pos - cache.pos) < window
    qf = q.astype(jnp.float32) * hd ** -0.5

    if perf.enabled("grouped_decode") and hl == num_heads and group > 1:
        # §Perf `grouped_decode`: keep the GQA group structure in the einsum
        # instead of expanding the cache to per-query-head — the cache is
        # read ONCE (B,S,KV,hd) rather than group-times.
        qr = qf.reshape(b, kv, group, hd)
        kf = cache.k.astype(jnp.float32)
        s = jnp.einsum("bkgd,bskd->bkgs", qr, kf)
        s = _softcap(s, softcap)
        s = jnp.where(ok[None, None, None, :], s, NEG_INF)
        m_glob = ctx.pmax_model(jnp.max(s, axis=-1))
        p = jnp.exp(s - m_glob[..., None])
        p = jnp.where(ok[None, None, None, :], p, 0.0)
        l_glob = ctx.psum_model(jnp.sum(p, axis=-1))
        o = ctx.psum_model(jnp.einsum(
            "bkgs,bskd->bkgd", p, cache.v.astype(jnp.float32)))
        o = o / jnp.maximum(l_glob, 1e-30)[..., None]
        return o.reshape(b, hl, hd).astype(q.dtype)

    # baseline: map each local q head to its kv group and expand
    head_offset = ctx.model_index() * hl if hl < num_heads else 0
    my_heads = head_offset + jnp.arange(hl)
    kv_idx = my_heads // group                                    # (hl,)
    k_sel = jnp.take(cache.k, kv_idx, axis=2).astype(jnp.float32)  # (B,S,hl,hd)
    v_sel = jnp.take(cache.v, kv_idx, axis=2).astype(jnp.float32)

    s = jnp.einsum("bhd,bshd->bhs", qf, k_sel)
    s = _softcap(s, softcap)
    s = jnp.where(ok[None, None, :], s, NEG_INF)

    m_loc = jnp.max(s, axis=-1)                                   # (B, hl)
    m_glob = ctx.pmax_model(m_loc)
    p = jnp.exp(s - m_glob[..., None])
    p = jnp.where(ok[None, None, :], p, 0.0)
    l_glob = ctx.psum_model(jnp.sum(p, axis=-1))
    o = ctx.psum_model(jnp.einsum("bhs,bshd->bhd", p, v_sel))
    return (o / jnp.maximum(l_glob, 1e-30)[..., None]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA layer front-ends (sequence / single-token)
# ---------------------------------------------------------------------------


def _project_qkv(p: dict, cfg: ModelConfig, x: Array, positions: Array):
    """Shared q/k/v projection + qk-norm + rope.  x: (B, S, d)."""
    hd = cfg.hd
    q = col_linear(x, p["wq"], p.get("bq"))
    k = col_linear(x, p["wk"], p.get("bk"))     # wk replicated ⇒ full kv heads
    v = col_linear(x, p["wv"], p.get("bv"))
    b, s, _ = x.shape
    q = q.reshape(b, s, -1, hd)
    k = k.reshape(b, s, -1, hd)
    v = v.reshape(b, s, -1, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions[None, :], cfg.rope_theta, cfg.rope_style)
    k = apply_rope(k, positions[None, :], cfg.rope_theta, cfg.rope_style)
    return q, k, v


def _row_out(out_flat: Array, wo: Array, ctx: ShardCtx,
             sharded: bool) -> Array:
    """Output projection: row-parallel (+psum) when the heads are sharded,
    plain replicated matmul when the head count didn't divide tp and the
    whole attention runs replicated (e.g. recurrentgemma's 10 heads)."""
    if sharded:
        return row_linear(out_flat, wo, ctx)
    return jnp.einsum("...i,io->...o", out_flat, wo)


def gqa_sequence(p: dict, cfg: ModelConfig, x: Array, positions: Array,
                 ctx: ShardCtx, *, is_swa: bool,
                 cache: AttnCache | None = None):
    """Full-sequence GQA (train or prefill).  Returns (out, new_cache)."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    kv_total = cfg.num_kv_heads
    kvl = k.shape[2]
    hl = q.shape[2]
    sharded = hl < cfg.num_heads           # heads divide tp => params sliced
    # compute-side GQA: wk is replicated, so every shard computed ALL kv
    # heads.  Select one kv head per LOCAL q head (g = 1 layout) so grouping
    # stays exact for any (heads, kv_heads, tp) combination.
    if kvl == kv_total and ctx.tp > 1:
        group = cfg.num_heads // kv_total
        offset = ctx.model_index() * hl if sharded else 0
        my_heads = offset + jnp.arange(hl)
        kv_idx = my_heads // group
        k_use = jnp.take(k, kv_idx, axis=2)
        v_use = jnp.take(v, kv_idx, axis=2)
    else:
        k_use, v_use = k, v
    if is_swa:
        out = local_attention(q, k_use, v_use, positions, cfg.swa_window,
                              cfg.softcap)
    else:
        out = flash_attention(q, k_use, v_use, positions, positions,
                              softcap=cfg.softcap)
    b, s = out.shape[0], out.shape[1]
    y = _row_out(out.reshape(b, s, -1), p["wo"], ctx, sharded)
    if cache is not None:
        cache = cache_write_prefill(cache, k, v, positions, ctx)
    return y, cache


def gqa_decode(p: dict, cfg: ModelConfig, x1: Array, pos: Array,
               cache: AttnCache, ctx: ShardCtx, *, is_swa: bool):
    """One-token GQA decode.  x1: (B, d).  Returns (out (B, d), new_cache).

    The decode parallelism axis is the SEQUENCE (the cache is seq-sharded
    over ``model``), so every shard must attend with ALL query heads over
    its slice: the head-sharded q is all-gathered first (tiny: B x H x hd),
    the lse-combine yields the replicated full-head output, and each shard
    slices its own heads back out for the row-parallel wo psum."""
    q, k, v = _project_qkv(p, cfg, x1[:, None, :], pos[None])
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]          # (B, Hl/KV, hd)
    hl = q1.shape[1]
    sharded = hl < cfg.num_heads
    cache = cache_write_token(cache, k1, v1, pos, ctx)
    window = cfg.swa_window if is_swa else 0
    if sharded:
        q_full = ctx.all_gather_model(q1, axis=1)   # (B, H, hd)
    else:
        q_full = q1
    out = decode_attention(q_full, cache, pos, ctx, num_heads=cfg.num_heads,
                           window=window, softcap=cfg.softcap)
    if sharded:
        out = jax.lax.dynamic_slice_in_dim(
            out, ctx.model_index() * hl, hl, axis=1)
    # decode_attention already psums over `model` (seq combine); the wo
    # projection psums again ONLY when the heads are genuinely sharded.
    y = _row_out(out.reshape(out.shape[0], -1), p["wo"], ctx, sharded)
    return y, cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------


class MLACache(NamedTuple):
    """Latent cache: c_kv (B, S_loc, kv_lora) + k_rope (B, S_loc, rope_hd),
    sequence-sharded like AttnCache."""
    ckv: Array
    krope: Array
    pos: Array


def init_mla_cache(batch: int, seq: int, cfg: ModelConfig, ctx: ShardCtx,
                   dtype) -> MLACache:
    m = cfg.mla
    s_loc = seq // ctx.tp
    return MLACache(
        ckv=jnp.zeros((batch, s_loc, m.kv_lora_rank), dtype),
        krope=jnp.zeros((batch, s_loc, m.rope_head_dim), dtype),
        pos=jnp.full((s_loc,), -1, jnp.int32),
    )


def _mla_qkv_latent(p: dict, cfg: ModelConfig, x: Array, positions: Array):
    """Shared down-projections.  Returns (q_nope, q_rope, ckv, krope)."""
    m = cfg.mla
    b, s, _ = x.shape
    cq = rms_norm(col_linear(x, p["w_dq"]), p["q_norm"])     # replicated
    q = col_linear(cq, p["w_uq"]).reshape(b, s, -1, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = apply_rope(q_rope, positions[None, :], cfg.rope_theta, "full")

    dkv = col_linear(x, p["w_dkv"])                           # replicated
    ckv = rms_norm(dkv[..., : m.kv_lora_rank], p["kv_norm"])
    krope = dkv[..., m.kv_lora_rank:]
    krope = apply_rope(krope[:, :, None, :], positions[None, :],
                       cfg.rope_theta, "full")[:, :, 0, :]
    return q_nope, q_rope, ckv, krope


def mla_sequence(p: dict, cfg: ModelConfig, x: Array, positions: Array,
                 ctx: ShardCtx, cache: MLACache | None = None):
    """Full-sequence MLA (unabsorbed): per-shard heads expand the latent."""
    m = cfg.mla
    b, s, _ = x.shape
    q_nope, q_rope, ckv, krope = _mla_qkv_latent(p, cfg, x, positions)
    hl = q_nope.shape[2]
    k_nope = col_linear(ckv, p["w_uk"]).reshape(b, s, hl, m.nope_head_dim)
    v = col_linear(ckv, p["w_uv"]).reshape(b, s, hl, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :], (b, s, hl, m.rope_head_dim))],
        axis=-1)
    # v head dim differs from qk head dim -> pad v for the shared flash core
    pad = q.shape[-1] - m.v_head_dim
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    out = flash_attention(q, k, v_p, positions, positions)[..., : m.v_head_dim]
    y = row_linear(out.reshape(b, s, -1), p["wo"], ctx)
    if cache is not None:
        s_loc = cache.ckv.shape[1]
        idx, valid, src = _ring_sources(s, s_loc, ctx)
        cache = MLACache(
            ckv=jnp.where(valid[None, :, None], ckv[:, idx],
                          cache.ckv).astype(cache.ckv.dtype),
            krope=jnp.where(valid[None, :, None], krope[:, idx],
                            cache.krope).astype(cache.krope.dtype),
            pos=jnp.where(valid, src, cache.pos).astype(jnp.int32),
        )
    return y, cache


def mla_decode(p: dict, cfg: ModelConfig, x1: Array, pos: Array,
               cache: MLACache, ctx: ShardCtx):
    """Absorbed single-token MLA over the sequence-sharded latent cache."""
    m = cfg.mla
    b = x1.shape[0]
    q_nope, q_rope, ckv, krope = _mla_qkv_latent(
        p, cfg, x1[:, None, :], pos[None])
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]       # (B, hl, nope/rope)
    ckv1, krope1 = ckv[:, 0], krope[:, 0]

    # write latent into the seq-sharded cache
    tmp = AttnCache(k=cache.ckv[:, :, None, :], v=cache.krope[:, :, None, :],
                    pos=cache.pos)
    tmp = cache_write_token(tmp, ckv1[:, None, :], krope1[:, None, :], pos, ctx)
    cache = MLACache(ckv=tmp.k[:, :, 0, :], krope=tmp.v[:, :, 0, :], pos=tmp.pos)

    # absorbed q: per-head, computed with the LOCAL head slice of w_uk, then
    # all-gathered to FULL heads — the decode parallelism axis is the
    # sequence (latent cache is seq-sharded), so every shard must score all
    # heads over its slice (same structure as gqa_decode).
    hl = q_nope.shape[1]
    sharded = hl < cfg.num_heads
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, hl, m.nope_head_dim)
    q_abs = jnp.einsum("bhn,chn->bhc", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))       # (B, hl, kv_lora)
    if sharded:
        q_abs = ctx.all_gather_model(q_abs, axis=1)    # (B, H, kv_lora)
        q_rope = ctx.all_gather_model(q_rope, axis=1)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    s_lat = jnp.einsum("bhc,bsc->bhs", q_abs,
                       cache.ckv.astype(jnp.float32))
    s_rope = jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                        cache.krope.astype(jnp.float32))
    s = (s_lat + s_rope) * scale
    ok = (cache.pos >= 0) & (cache.pos <= pos)
    s = jnp.where(ok[None, None, :], s, NEG_INF)

    m_loc = jnp.max(s, axis=-1)
    m_glob = ctx.pmax_model(m_loc)
    pw = jnp.exp(s - m_glob[..., None])
    pw = jnp.where(ok[None, None, :], pw, 0.0)
    l_glob = ctx.psum_model(jnp.sum(pw, axis=-1))
    ctx_lat = ctx.psum_model(
        jnp.einsum("bhs,bsc->bhc", pw, cache.ckv.astype(jnp.float32)))
    ctx_lat = ctx_lat / jnp.maximum(l_glob, 1e-30)[..., None]
    if sharded:
        ctx_lat = jax.lax.dynamic_slice_in_dim(
            ctx_lat, ctx.model_index() * hl, hl, axis=1)

    w_uv = p["w_uv"].reshape(m.kv_lora_rank, hl, m.v_head_dim)
    out = jnp.einsum("bhc,chv->bhv", ctx_lat, w_uv.astype(jnp.float32))
    y = _row_out(out.reshape(b, -1).astype(x1.dtype), p["wo"], ctx,
                 sharded or ctx.model_axis is None)
    return y, cache
