"""Layer assembly: one decoder layer = norm → mixer → residual
(→ norm → mlp → residual), with the mixer/mlp kinds chosen per LayerSpec.

The repeated pattern is executed under `lax.scan` over stacked per-repeat
params (+ per-repeat caches in serve mode), keeping HLO size O(pattern).
`jax.checkpoint` wraps the scan body in training (remat policy is a §Perf
knob).  Enc-dec decoder layers additionally carry cross-attention.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import mlp, mlp_params, rms_norm, rms_norm_params
from repro.sharding.ctx import ShardCtx

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def layer_params(cfg: ModelConfig, spec: LayerSpec, key, dtype,
                 cross_attn: bool = False) -> dict:
    d = cfg.d_model
    k_mix, k_mlp, k_cross = jax.random.split(key, 3)
    p: dict = {"norm1": rms_norm_params(d, dtype)}
    if spec.mixer in ("attn", "swa"):
        p["mixer"] = attn.attn_params(cfg, k_mix, dtype)
    elif spec.mixer == "mla":
        p["mixer"] = attn.mla_params(cfg, k_mix, dtype)
    elif spec.mixer == "ssd":
        p["mixer"] = ssm_mod.ssd_params(cfg, k_mix, dtype)
    elif spec.mixer == "rglru":
        p["mixer"] = rglru_mod.rglru_params(cfg, k_mix, dtype)
    else:
        raise ValueError(spec.mixer)
    if cross_attn:
        p["norm_cross"] = rms_norm_params(d, dtype)
        p["cross"] = attn.attn_params(cfg, k_cross, dtype)
    if cfg.d_ff > 0 or spec.mlp == "moe":
        p["norm2"] = rms_norm_params(d, dtype)
        if spec.mlp == "moe":
            p["mlp"] = moe_mod.moe_params(cfg, k_mlp, dtype)
        else:
            p["mlp"] = mlp_params(k_mlp, d, cfg.d_ff, dtype)
    return p


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     seq_len: int, ctx: ShardCtx, dtype) -> PyTree:
    if spec.mixer == "attn":
        return attn.init_attn_cache(batch, seq_len, cfg.num_kv_heads, cfg.hd,
                                    ctx, dtype)
    if spec.mixer == "swa":
        w = swa_ring_size(cfg.swa_window, seq_len)
        assert w % ctx.tp == 0, (w, ctx.tp)
        return attn.init_attn_cache(batch, w, cfg.num_kv_heads, cfg.hd,
                                    ctx, dtype)
    if spec.mixer == "mla":
        return attn.init_mla_cache(batch, seq_len, cfg, ctx, dtype)
    if spec.mixer == "ssd":
        return ssm_mod.init_ssd_cache(batch, cfg, ctx, dtype)
    if spec.mixer == "rglru":
        return rglru_mod.init_rglru_cache(batch, cfg, ctx, dtype)
    raise ValueError(spec.mixer)


def swa_ring_size(window: int, seq_len: int) -> int:
    """SWA ring-cache size: >= window + 1 slots (the newest token must never
    evict a still-visible one), rounded to a multiple of 256 so the ring
    shards evenly over any tp <= 256, capped at the full sequence.

    tp-INDEPENDENT by construction: global cache shapes must agree between
    the sharded runtime and the unsharded abstract-shape path."""
    ring = ((window // 256) + 1) * 256
    return min(ring, seq_len)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def layer_seq(cfg: ModelConfig, spec: LayerSpec, p: dict, x: Array,
              positions: Array, ctx: ShardCtx, cache: PyTree | None,
              enc_out: Array | None = None):
    """Full-sequence layer (train when cache is None, else prefill).

    Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm1"])
    if spec.mixer in ("attn", "swa"):
        y, cache = attn.gqa_sequence(p["mixer"], cfg, h, positions, ctx,
                                     is_swa=spec.mixer == "swa", cache=cache)
    elif spec.mixer == "mla":
        y, cache = attn.mla_sequence(p["mixer"], cfg, h, positions, ctx,
                                     cache=cache)
    elif spec.mixer == "ssd":
        y, cache = ssm_mod.ssd_sequence(p["mixer"], cfg, h, ctx,
                                        want_cache=cache is not None)
    elif spec.mixer == "rglru":
        y, cache = rglru_mod.rglru_sequence(p["mixer"], cfg, h, ctx,
                                            want_cache=cache is not None)
    else:
        raise ValueError(spec.mixer)
    x = x + y.astype(x.dtype)

    if "cross" in p and enc_out is not None:
        h = rms_norm(x, p["norm_cross"])
        y = _cross_attention_seq(p["cross"], cfg, h, enc_out, ctx)
        x = x + y.astype(x.dtype)

    if "mlp" in p:
        h = rms_norm(x, p["norm2"])
        if spec.mlp == "moe":
            y, aux = moe_mod.moe_mlp(p["mlp"], cfg, h, ctx)
        else:
            y = mlp(p["mlp"], h, ctx)
        x = x + y.astype(x.dtype)
    return x, cache, aux


def layer_decode(cfg: ModelConfig, spec: LayerSpec, p: dict, x1: Array,
                 pos: Array, cache: PyTree, ctx: ShardCtx,
                 enc_out: Array | None = None):
    """Single-token layer step.  x1: (B, d).  Returns (x1, new_cache)."""
    h = rms_norm(x1, p["norm1"])
    if spec.mixer in ("attn", "swa"):
        y, cache = attn.gqa_decode(p["mixer"], cfg, h, pos, cache, ctx,
                                   is_swa=spec.mixer == "swa")
    elif spec.mixer == "mla":
        y, cache = attn.mla_decode(p["mixer"], cfg, h, pos, cache, ctx)
    elif spec.mixer == "ssd":
        y, cache = ssm_mod.ssd_decode(p["mixer"], cfg, h, cache, ctx)
    elif spec.mixer == "rglru":
        y, cache = rglru_mod.rglru_decode(p["mixer"], cfg, h, cache, ctx)
    else:
        raise ValueError(spec.mixer)
    x1 = x1 + y.astype(x1.dtype)

    if "cross" in p and enc_out is not None:
        h = rms_norm(x1, p["norm_cross"])
        y = _cross_attention_seq(p["cross"], cfg, h[:, None, :], enc_out,
                                 ctx)[:, 0, :]
        x1 = x1 + y.astype(x1.dtype)

    if "mlp" in p:
        h = rms_norm(x1, p["norm2"])
        if spec.mlp == "moe":
            y, _ = moe_mod.moe_mlp(p["mlp"], cfg, h[:, None, :], ctx)
            y = y[:, 0, :]
        else:
            y = mlp(p["mlp"], h, ctx)
        x1 = x1 + y.astype(x1.dtype)
    return x1, cache


def _cross_attention_seq(p: dict, cfg: ModelConfig, x: Array, enc_out: Array,
                         ctx: ShardCtx) -> Array:
    """Bidirectional cross-attention: q from decoder x, kv from encoder
    output (replicated; source lengths are short).  No rope."""
    from repro.models.layers import col_linear, row_linear

    b, s, _ = x.shape
    t = enc_out.shape[1]
    hd = cfg.hd
    q = col_linear(x, p["wq"], p.get("bq")).reshape(b, s, -1, hd)
    k = col_linear(enc_out, p["wk"], p.get("bk")).reshape(b, t, -1, hd)
    v = col_linear(enc_out, p["wv"], p.get("bv")).reshape(b, t, -1, hd)
    hl = q.shape[2]
    sharded = hl < cfg.num_heads
    if k.shape[2] == cfg.num_kv_heads and ctx.tp > 1:
        group = cfg.num_heads // cfg.num_kv_heads
        offset = ctx.model_index() * hl if sharded else 0
        my = offset + jnp.arange(hl)
        k = jnp.take(k, my // group, axis=2)
        v = jnp.take(v, my // group, axis=2)
    # all kv visible: q_pos = T for every query, kv_pos = 0..T-1
    qpos = jnp.full((s,), t, jnp.int32)
    kpos = jnp.arange(t, dtype=jnp.int32)
    out = attn.flash_attention(q, k, v, qpos, kpos)
    if sharded:
        return row_linear(out.reshape(b, s, -1), p["wo"], ctx)
    return jnp.einsum("...i,io->...o", out.reshape(b, s, -1), p["wo"])
