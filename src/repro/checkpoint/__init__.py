from repro.checkpoint.ckpt import (
    restore,
    restore_training,
    save,
    save_training,
)

__all__ = ["restore", "restore_training", "save", "save_training"]
