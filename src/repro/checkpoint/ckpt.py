"""Minimal checkpointing: pytree <-> .npz with path-keyed arrays + a JSON
metadata sidecar (step, transmitted bits, config name).  No external deps.

`save_training`/`restore_training` bundle the THREE live trees of a run —
params, opt_state, and the aggregator's `CommState` — into one checkpoint.
Before the CommState became first-class, checkpoints silently dropped the
EF21 innovation state: a restored EF21/EF21-SGDM run restarted from zero
innovation (and an adaptive-MLMC run from a cold probability ladder).
Persisting the comm state makes restore-and-continue bitwise identical to
an uninterrupted run (see tests/test_comm_state.py)."""

from __future__ import annotations

import json
import pathlib
from typing import Any

import jax
import numpy as np

PyTree = Any

_SEP = "|"


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        elif hasattr(e, "name"):
            parts.append(str(e.name))
        else:
            parts.append(str(e))
    return _SEP.join(parts)


def save(path: str | pathlib.Path, tree: PyTree,
         metadata: dict | None = None) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = {}
    for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
            # np.savez can't serialize ml_dtypes (bf16/f8): widen to f32
            # (exact for bf16); `restore` casts back to the template dtype
            arr = np.asarray(leaf, np.float32)
        flat[_path_str(p)] = arr
    np.savez(path.with_suffix(".npz"), **flat)
    meta = dict(metadata or {})
    path.with_suffix(".json").write_text(json.dumps(meta, indent=1))


def save_training(path: str | pathlib.Path, *, params: PyTree,
                  opt_state: PyTree = (), comm_state: PyTree = (),
                  metadata: dict | None = None) -> None:
    """Persist one training bundle: params + optimizer state + CommState."""
    save(path, {"params": params, "opt_state": opt_state,
                "comm_state": comm_state}, metadata)


def restore_training(path: str | pathlib.Path, *, params: PyTree,
                     opt_state: PyTree = (), comm_state: PyTree = ()
                     ) -> tuple[PyTree, PyTree, PyTree, dict]:
    """Restore a `save_training` bundle into the given templates.

    Returns ``(params, opt_state, comm_state, metadata)``.  A checkpoint
    written without a comm state will raise `KeyError` when restored with a
    stateful template — better loud than an EF21 run silently restarting
    from zero innovation."""
    tree, meta = restore(path, {"params": params, "opt_state": opt_state,
                                "comm_state": comm_state})
    return tree["params"], tree["opt_state"], tree["comm_state"], meta


def restore(path: str | pathlib.Path, like: PyTree) -> tuple[PyTree, dict]:
    """Restore into the structure of `like` (shape/dtype template)."""
    path = pathlib.Path(path)
    data = np.load(path.with_suffix(".npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in paths:
        key = _path_str(p)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    meta_file = path.with_suffix(".json")
    meta = json.loads(meta_file.read_text()) if meta_file.exists() else {}
    return jax.tree_util.tree_unflatten(treedef, leaves), meta
