"""Pure-function optimizers over arbitrary pytrees."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    #: (grads, state, params) -> (new_params, new_state)
    apply: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    #: param_specs pytree -> opt-state PartitionSpec pytree (mirrors init)
    state_specs: Callable[[PyTree], PyTree] = lambda ps: ()
    name: str = "opt"


def sgd(lr: float) -> Optimizer:
    def init(params):
        del params
        return ()

    def apply(grads, state, params):
        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, state

    return Optimizer(init, apply, lambda ps: (), "sgd")


def momentum_sgd(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def apply(grads, state, params):
        new_m = jax.tree.map(
            lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        new_p = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, new_m)
        return new_p, new_m

    return Optimizer(init, apply, lambda ps: ps, "momentum_sgd")


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "t": jnp.zeros((), jnp.int32)}

    def apply(grads, state, params):
        t = state["t"] + 1
        bc1 = 1.0 - b1 ** t.astype(jnp.float32)
        bc2 = 1.0 - b2 ** t.astype(jnp.float32)

        new_m = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        new_v = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)

        def upd(p, m, v):
            step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step + lr * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step).astype(p.dtype)

        new_p = jax.tree.map(upd, params, new_m, new_v)
        return new_p, {"m": new_m, "v": new_v, "t": t}

    def state_specs(ps):
        from jax.sharding import PartitionSpec as P

        return {"m": ps, "v": ps, "t": P()}

    return Optimizer(init, apply, state_specs, "adamw")
