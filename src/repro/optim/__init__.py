"""repro.optim — minimal pure-JAX optimizers (SGD / momentum / AdamW).

The paper's experiments run SGD (+ EF21-SGDM's momentum living in the
*aggregator*, not here).  Optimizers are compression-agnostic: they consume
whatever aggregated gradient estimate the trainer hands them."""

from repro.optim.optimizers import Optimizer, adamw, momentum_sgd, sgd

__all__ = ["Optimizer", "adamw", "momentum_sgd", "sgd"]
