"""Learning-rate schedules + gradient clipping — production trainer knobs.

`scheduled(make_opt, schedule)` rebuilds the base optimizer's update with a
step-indexed learning rate; `with_global_clip(opt, max_norm)` rescales the
incoming gradient estimate before the base update (clipping the MLMC
estimate is still a valid SGD method — clipping acts on the aggregate)."""

from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer

PyTree = Any


def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  min_frac: float = 0.1) -> Callable:
    """Step -> lr: linear warmup then cosine decay to min_frac*base."""

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * (step + 1.0) / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1.0 + jnp.cos(math.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr


def scheduled(make_opt: Callable[[float], Optimizer],
              schedule: Callable) -> Optimizer:
    """Wrap an lr-parameterized optimizer factory with a schedule.

    The base optimizer is built at lr=1.0 and the schedule scales the
    gradient (exact for SGD/momentum, the standard scaling for adamw)."""
    base = make_opt(1.0)

    def init(params):
        return {"base": base.init(params), "step": jnp.zeros((), jnp.int32)}

    def apply(grads, state, params):
        lr = schedule(state["step"])
        scaled = jax.tree.map(lambda g: g * lr, grads)
        new_params, new_base = base.apply(scaled, state["base"], params)
        return new_params, {"base": new_base, "step": state["step"] + 1}

    def state_specs(ps):
        from jax.sharding import PartitionSpec as P

        return {"base": base.state_specs(ps), "step": P()}

    return Optimizer(init, apply, state_specs, f"scheduled({base.name})")


def with_global_clip(opt: Optimizer, max_norm: float) -> Optimizer:
    """Clip the aggregated gradient estimate to a global L2 norm."""

    def apply(grads, state, params):
        sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                 for g in jax.tree.leaves(grads))
        norm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
        clipped = jax.tree.map(lambda g: g * scale, grads)
        return opt.apply(clipped, state, params)

    return Optimizer(opt.init, apply, opt.state_specs,
                     f"clip({opt.name},{max_norm})")
