"""Wire fast-path benchmark — the compiled codec pipeline vs the field.

Measures, at the two BENCH_adaptive model sizes:

* trainer steps/s of the packed byte wire running ``mlmc_topk`` through
  the COMPILED codec pipeline (`repro.comm.compiled`) against the
  fully-jitted abstract reference ``mlmc_topk_static`` — the acceptance
  target is packed within 15% of the jitted reference (the eager host
  loop used to sit ~45% behind it);
* the same method on the abstract wire (adaptive MLMC context) and, at
  the small size, through the ORIGINAL eager codecs
  (``wire_compiled=False``) — the before/after of this PR;
* per-codec encode/decode microbenchmarks (µs/op, eager vs compiled) at
  the small model's gradient dimension.

Emits a machine-readable ``BENCH_wire.json`` at the REPO ROOT so
successive PRs accumulate a comparable perf record:

    PYTHONPATH=src python -m benchmarks.bench_wire            # full
    PYTHONPATH=src python -m benchmarks.bench_wire --smoke    # CI tier

The smoke tier (a few steps, one size, tiny micro dims) exercises the
emission path on every push without burning minutes and NEVER clobbers a
committed full record; the weekly full run refreshes the real numbers.
"""

from __future__ import annotations

import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.common import run_methods, small_lm_config

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_wire.json"
#: every run also records a comm-stack trace (spans + MLMC estimator
#: telemetry) — CI validates it against the checked-in schema, converts
#: it with the Perfetto exporter, and uploads it as a build artifact
TRACE_PATH = REPO_ROOT / "TRACE_wire.jsonl"

#: the BENCH_adaptive sizes, for record-to-record comparability
SIZES = {
    "small": dict(layers=2, d_model=128),
    "wide": dict(layers=2, d_model=256),
}

#: codecs micro-benchmarked per record (a spread of stream shapes: sparse
#: segment, dense packed codes, 1-bit plane, raw-f32 innovation, and the
#: entropy-coded mlmc_rtn corr stream — the one wire format this PR
#: changed, whose gamma decode is part host-sequential and must stay
#: measured)
MICRO_CODECS = ("mlmc_topk", "qsgd", "signsgd", "ef21", "mlmc_rtn")


def _bits_columns(label: str, r: dict, kw: dict) -> dict:
    """Honest, COMPARABLE bit columns per entry.

    ``bits_per_step`` used to mix units: the abstract ledger's idealized
    bits for abstract runs, but measured packet bytes (headers + ext lane
    + word padding included) for packed runs — 2320056 vs 2855576 at
    d=557696 in the previous record looked like a codec regression and was
    an accounting artifact.  Now every entry reports BOTH columns:
    ``ledger_bits`` (the `repro.core.bits` idealized cost; the nominal
    value for packed runs) and ``measured_bits`` (real packet bits; None
    for abstract runs, which ship nothing).  A representative encode is
    additionally asserted against the codec's reconcile bounds — the same
    contract as tests/test_comm.py::test_bits_reconcile — so the two
    columns can never silently drift apart."""
    from benchmarks.common import BENCH_WORKERS
    from repro.comm import make_codec

    bits_per_step = r["bits"][-1] / max(len(r["bits"]), 1)
    cols = {"bits_per_step": bits_per_step}
    if kw.get("wire") != "packed":
        cols["ledger_bits"] = bits_per_step
        cols["measured_bits"] = None
        return cols
    codec = make_codec(kw["method"], r["dim"],
                       k_fraction=kw.get("k_fraction", 0.02))
    v = jax.random.normal(jax.random.PRNGKey(7), (r["dim"],), jnp.float32)
    pkt = codec.encode(v, jax.random.PRNGKey(8)).packet
    measured = float(codec.measured_bits(pkt))
    lo, hi = codec.reconcile_bounds(pkt)
    assert lo <= measured <= hi, \
        (label, measured, (lo, hi), codec.nominal_bits())
    cols["ledger_bits"] = float(codec.nominal_bits()) * BENCH_WORKERS
    cols["measured_bits"] = bits_per_step
    cols["reconcile"] = {"one_packet_measured": measured,
                         "bounds": [float(lo), float(hi)],
                         "nominal": float(codec.nominal_bits())}
    return cols


def _trainer_entries(size_name: str, steps: int, smoke: bool) -> dict:
    cfg = small_lm_config(**SIZES[size_name])
    methods = {
        "mlmc_topk_static_abstract": dict(method="mlmc_topk_static",
                                          k_fraction=0.02),
        "mlmc_topk_packed": dict(method="mlmc_topk", k_fraction=0.02,
                                 wire="packed"),
        # bucketed overlap path: per-bucket encodes streamed off the
        # backward taps (repro.comm.plan); acceptance wants steps/s >= the
        # non-bucketed packed fast path.  128k buckets measured best at
        # both sizes — more buckets buys more overlap but pays more
        # per-bucket dispatch against a CPU backward that already owns
        # every core (65536 at d=558k: 0.85x; 131072: 1.0-1.2x)
        "mlmc_topk_packed_bucketed": dict(method="mlmc_topk",
                                          k_fraction=0.02, wire="packed",
                                          bucket_size=131072),
        "mlmc_topk_abstract": dict(method="mlmc_topk", k_fraction=0.02),
    }
    if size_name == "small" and not smoke:
        # the "before": the eager per-worker host loop (few steps — it is
        # exactly the path this PR retires)
        methods["mlmc_topk_packed_eager"] = dict(
            method="mlmc_topk", k_fraction=0.02, wire="packed",
            wire_compiled=False)
    results = run_methods(methods, steps=steps, cfg=cfg)
    out = {}
    for label, r in results.items():
        out[label] = {
            "dim": r["dim"],
            "steps_per_s": round(len(r["loss"]) / max(r["wall_s"], 1e-9), 3),
            "final_loss": round(r["final_loss"], 6),
            **_bits_columns(label, r, methods[label]),
        }
    ref = out["mlmc_topk_static_abstract"]["steps_per_s"]
    packed = out["mlmc_topk_packed"]["steps_per_s"]
    bucketed = out["mlmc_topk_packed_bucketed"]["steps_per_s"]
    return {
        "trainer": out,
        # acceptance: packed mlmc_topk within 15% of the jitted reference
        "packed_vs_static_ratio": round(packed / max(ref, 1e-9), 3),
        # acceptance: bucketed streaming at least matches the flat path
        "bucketed_vs_packed_ratio": round(bucketed / max(packed, 1e-9), 3),
    }


def _micro_us(fn, *args, repeats: int = 5) -> float:
    fn(*args)                                  # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return round(best * 1e6, 1)


def _codec_micro(dim: int) -> dict:
    from repro.comm import make_codec, make_compiled_codec

    v = jax.random.normal(jax.random.PRNGKey(0), (dim,), jnp.float32)
    v = (v * jnp.exp(-10.0 * jnp.arange(dim) / dim)).block_until_ready()
    key = jax.random.PRNGKey(1)
    out = {}
    for name in MICRO_CODECS:
        eager = make_codec(name, dim, k_fraction=0.02, s=4)
        comp = make_compiled_codec(name, dim, k_fraction=0.02, s=4)
        pkt = comp.encode(v, key).packet

        def enc_eager():
            eager.encode(v, key)

        def enc_comp():
            comp.encode(v, key)

        def dec_eager():
            eager.decode(pkt)

        def dec_comp():
            # includes the host staging copy + the jitted decode
            comp.decode(pkt)

        out[name] = {
            "encode_eager_us": _micro_us(enc_eager),
            "encode_compiled_us": _micro_us(enc_comp),
            "decode_eager_us": _micro_us(dec_eager),
            "decode_compiled_us": _micro_us(dec_comp),
        }
    return out


def main(smoke: bool = False) -> dict:
    from repro import obs

    telemetry = obs.install(obs.Telemetry(sample_every=5))
    steps = 3 if smoke else 12
    sizes = ("small",) if smoke else ("small", "wide")
    record = {
        "benchmark": "wire_fast_path",
        "smoke": smoke,
        "steps": steps,
        "sizes": {},
    }
    for size_name in sizes:
        t0 = time.time()
        entry = _trainer_entries(size_name, steps, smoke)
        dim = entry["trainer"]["mlmc_topk_packed"]["dim"]
        entry["codec_us"] = _codec_micro(2048 if smoke else dim)
        for cname, row in entry["codec_us"].items():
            # the per-direction default table (compiled.default_compiled)
            # is set from these four columns — a record without them
            # cannot back the next re-measurement
            for col in ("encode_eager_us", "encode_compiled_us",
                        "decode_eager_us", "decode_compiled_us"):
                assert row.get(col), f"{cname}: {col} missing/zero"
        record["sizes"][size_name] = entry
        for label, r in entry["trainer"].items():
            print(f"bench_wire/{size_name}/{label},"
                  f"{1e6 / max(r['steps_per_s'], 1e-9):.0f},"
                  f"steps_per_s={r['steps_per_s']};"
                  f"final_loss={r['final_loss']:.4f}")
        print(f"# bench_wire {size_name} ratio packed/static = "
              f"{entry['packed_vs_static_ratio']} "
              f"({time.time() - t0:.1f}s)", flush=True)
    keep = False
    if smoke and OUT_PATH.exists():
        try:
            # never clobber a committed FULL perf record with a smoke
            # run (CI runs --smoke on every push to test this path)
            keep = not json.loads(OUT_PATH.read_text()).get("smoke", True)
        except (json.JSONDecodeError, OSError):
            pass
    if keep:
        print(f"# smoke run: kept existing full record {OUT_PATH}")
    else:
        OUT_PATH.write_text(json.dumps(record, indent=1) + "\n")
        print(f"# wrote {OUT_PATH}")
    _write_trace(telemetry)
    return record


def _write_trace(telemetry) -> None:
    from repro import obs

    events = obs.export.telemetry_events(telemetry)
    errors = obs.export.validate_events(events)
    if errors:                    # pragma: no cover - schema regression
        raise SystemExit(f"trace schema violations: {errors[:5]}")
    obs.export.write_jsonl(TRACE_PATH, events)
    print(f"# wrote {TRACE_PATH} ({len(events)} events, schema OK)")
    obs.install(None)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
