"""Lemma-validation table (no training): closed-form MLMC estimator
variances vs the unbiased baselines, across gradient decay profiles.

Validates numerically:
  * Lemma 3.3 / B.1 — p_l ∝ 2^-l is optimal for bit-wise ladders,
  * Lemma 3.4      — adaptive p beats static p for s-Top-k,
  * Lemma 3.6      — O(1/(r s)) vs Rand-k's O(d/s) under exp decay.
"""

import jax
import jax.numpy as jnp

from benchmarks.common import save_and_print
from repro.core import (
    FixedPointMultilevel,
    RandK,
    STopKMultilevel,
    adaptive_probs,
    mlmc_second_moment,
    optimal_second_moment,
)


def main(tag="variance_table") -> dict:
    d, s = 4096, 32
    rows = {}
    for r in [0.002, 0.01, 0.05]:
        v = jnp.exp(-r / 2 * jnp.arange(d, dtype=jnp.float32))
        norm2 = float(jnp.sum(v * v))
        comp = STopKMultilevel(d=d, s=s)
        var_adaptive = float(optimal_second_moment(comp, v)) - norm2
        var_static = float(mlmc_second_moment(comp, v,
                                              comp.static_probs())) - norm2
        var_randk = (d / s - 1.0) * norm2          # Rand-k, k = s budget
        lemma36 = (4.0 / (r * s) - 1.0) * norm2
        fp = FixedPointMultilevel(num_bits=16)
        var_fp_opt = float(mlmc_second_moment(fp, v)) - norm2
        uni = jnp.full((16,), 1 / 16.0)
        var_fp_uni = float(mlmc_second_moment(fp, v, uni)) - norm2
        rows[f"r={r}"] = {
            "var_mlmc_adaptive/norm2": var_adaptive / norm2,
            "var_mlmc_static/norm2": var_static / norm2,
            "var_randk/norm2": var_randk / norm2,
            "lemma36_bound/norm2": lemma36 / norm2,
            "var_fixed_optimal/norm2": var_fp_opt / norm2,
            "var_fixed_uniform/norm2": var_fp_uni / norm2,
            "adaptive<=static": var_adaptive <= var_static + 1e-6,
            "adaptive<randk": var_adaptive < var_randk,
            "fp_opt<=uniform": var_fp_opt <= var_fp_uni + 1e-6,
        }
        print(f"variance_table/r={r},0,"
              f"adaptive={var_adaptive/norm2:.3f};randk={var_randk/norm2:.3f};"
              f"bound={lemma36/norm2:.3f}")
    ok = all(row["adaptive<=static"] and row["adaptive<randk"]
             and row["fp_opt<=uniform"] for row in rows.values())
    save_and_print(tag, rows, derived=f"all_lemmas_hold={ok}")
    assert ok
    return rows


if __name__ == "__main__":
    main()
