"""Elastic deadline-aggregation benchmark — straggler cost vs deadline.

The PR-10 elastic star lets rank 0 close each aggregation round
``deadline_ms`` after it starts and serve whoever arrived, reweighted by
inverse participation (Horvitz-Thompson) so the run-mean direction stays
unbiased.  This benchmark runs a 4-rank threaded tcp world (real localhost
sockets) with one injected straggler and sweeps straggler delay x deadline,
reporting per entry:

* ``rounds_per_s`` — measured on rank 0 (the deadline's whole point: a
  straggler stops costing the world its delay);
* ``direction_err`` — ||run-mean direction - full-world mean|| / ||mean||,
  the unbiasedness price actually paid at this fault rate;
* ``partial_rounds`` and ``participation_mean`` from the recorded masks.

Emits ``BENCH_elastic.json`` at the REPO ROOT:

    PYTHONPATH=src python -m benchmarks.bench_elastic            # full
    PYTHONPATH=src python -m benchmarks.bench_elastic --smoke    # CI tier

The smoke tier never clobbers a committed full record (same contract as
``bench_wire`` / ``bench_downlink``).
"""

from __future__ import annotations

import json
import pathlib
import sys
import threading
import time

import numpy as np

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_elastic.json"

WORLD = 4
DIM = 1024
STRAGGLER = 3            # the last rank drags every round by ``delay_s``
#: ``None`` = synchronous semantics (a deadline no round ever hits)
SYNC_DEADLINE_MS = 30000.0


def _connect(world, deadline_ms):
    from repro.comm.multihost import TcpStarTransport

    server = TcpStarTransport.listen(port=0, world=world, timeout=30.0,
                                     deadline_ms=deadline_ms)
    tps = {0: server}

    def join(r):
        tps[r] = TcpStarTransport.connect(
            "127.0.0.1", server.port, rank=r, world=world, timeout=30.0,
            deadline_ms=deadline_ms)

    threads = [threading.Thread(target=join, args=(r,))
               for r in range(1, world)]
    for t in threads:
        t.start()
    server.accept_workers()
    for t in threads:
        t.join()
    return tps


def _run_one(delay_s: float, deadline_ms: float | None, rounds: int) -> dict:
    """One grid cell: dense aggregation of fixed per-rank gradients with
    rank ``STRAGGLER`` sleeping ``delay_s`` before every uplink."""
    import jax

    from repro.comm import Fault, FaultSchedule, FaultyTransport, \
        packed_aggregator

    rng = np.random.default_rng(0)
    grads = np.asarray(rng.normal(size=(WORLD, DIM)), np.float32)
    gbar = grads.astype(np.float64).mean(axis=0)
    # straggles every OTHER round: an always-late rank is simply censored
    # (nothing to reweight), an intermittent one exercises the
    # Horvitz-Thompson correction that keeps the run-mean unbiased
    sched = FaultSchedule({STRAGGLER: [Fault(t, "delay", delay_s)
                                       for t in range(0, rounds, 2)]}) \
        if delay_s > 0 else FaultSchedule()

    tps = _connect(WORLD, deadline_ms if deadline_ms is not None
                   else SYNC_DEADLINE_MS)
    aggs = {0: packed_aggregator("dense", DIM, transport=tps[0])}
    for r in range(1, WORLD):
        aggs[r] = packed_aggregator(
            "dense", DIM, transport=FaultyTransport(tps[r], sched))
    key = jax.random.PRNGKey(0)
    fail = []

    def worker(r):
        try:
            for t in range(rounds):
                aggs[r](grads[r:r + 1], key, None)
        except Exception as e:    # pragma: no cover - surfaced below
            fail.append((r, repr(e)))

    threads = [threading.Thread(target=worker, args=(r,))
               for r in range(1, WORLD)]
    for t in threads:
        t.start()
    dirs, masks = [], []
    t0 = time.perf_counter()
    for t in range(rounds):
        out = aggs[0](grads[0:1], key, None)
        dirs.append(np.asarray(out.direction, np.float64))
        mask = np.zeros(WORLD, bool)
        mask[tps[0].last_participation] = True
        masks.append(mask)
    wall = time.perf_counter() - t0
    for t in threads:
        t.join(timeout=120)
    for t in tps.values():
        t.close()
    if fail:
        raise RuntimeError(f"worker ranks failed: {fail}")
    dirs, masks = np.stack(dirs), np.stack(masks)
    err = float(np.linalg.norm(dirs.mean(axis=0) - gbar)
                / np.linalg.norm(gbar))
    return {
        "rounds_per_s": round(rounds / max(wall, 1e-9), 2),
        "direction_err": round(err, 6),
        "partial_rounds": int((~masks.all(axis=1)).sum()),
        "participation_mean": round(float(masks.sum(axis=1).mean()), 3),
    }


def main(smoke: bool = False) -> dict:
    rounds = 15 if smoke else 60
    # the deadline clock starts at the FIRST arrival, so the straggler
    # only misses the cut when its delay exceeds the deadline
    delays_ms = (0, 90) if smoke else (0, 90, 250)
    deadlines_ms = (None, 50.0)
    record = {"benchmark": "elastic", "smoke": smoke, "rounds": rounds,
              "world": WORLD, "dim": DIM, "straggler_rank": STRAGGLER,
              "grid": {}}
    for delay in delays_ms:
        for deadline in deadlines_ms:
            label = f"delay{delay}ms/" \
                    + ("sync" if deadline is None else f"dl{deadline:.0f}ms")
            t0 = time.time()
            r = _run_one(delay / 1000.0, deadline, rounds)
            record["grid"][label] = r
            print(f"bench_elastic/{label},"
                  f"{1e6 / max(r['rounds_per_s'], 1e-9):.0f},"
                  f"err={r['direction_err']:.4f};"
                  f"partial={r['partial_rounds']};"
                  f"part_mean={r['participation_mean']}"
                  f" ({time.time() - t0:.1f}s)", flush=True)
    # the headline: under a straggler the deadline arm serves rounds
    # faster than the synchronous arm at a bounded direction error
    slow = f"delay{delays_ms[-1]}ms"
    record["speedup_at_max_delay"] = round(
        record["grid"][f"{slow}/dl50ms"]["rounds_per_s"]
        / max(record["grid"][f"{slow}/sync"]["rounds_per_s"], 1e-9), 3)
    keep = False
    if smoke and OUT_PATH.exists():
        try:
            # never clobber a committed FULL perf record with a smoke run
            keep = not json.loads(OUT_PATH.read_text()).get("smoke", True)
        except (json.JSONDecodeError, OSError):
            pass
    if keep:
        print(f"# smoke run: kept existing full record {OUT_PATH}")
    else:
        OUT_PATH.write_text(json.dumps(record, indent=1) + "\n")
        print(f"# wrote {OUT_PATH}")
    return record


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
