"""Pallas kernel micro-bench: us/call for each compression kernel at
gradient-scale sizes.  On this CPU container the kernels execute via
interpret=True (upper bound); the same code compiles natively on TPU."""

import time

import jax
import jax.numpy as jnp

from benchmarks.common import save_and_print
from repro.comm import make_codec, make_device_codec, pack_bits, \
    pack_planes, unpack_bits, unpack_planes
from repro.comm.device_wire import ternary_words, topk_segment_words
from repro.kernels import ops


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def main(tag="kernel_bench") -> dict:
    d = 1 << 22  # 4M-element gradient bucket
    v = jax.random.normal(jax.random.PRNGKey(0), (d,))
    scale = jnp.max(jnp.abs(v))
    res = {}
    res["bitplane_residual"] = _time(
        lambda: ops.bitplane_residual(v, scale, 7))
    res["ternary_bitplane"] = _time(
        lambda: ops.ternary_bitplane(v, scale, 7))
    res["rtn_quantize"] = _time(lambda: ops.rtn_quantize(v, scale, 4))
    res["exp_histogram"] = _time(lambda: ops.exp_histogram(v))
    res["band_select"] = _time(
        lambda: ops.band_select(v, jnp.float32(0.1), jnp.float32(1.0)))
    sv = jnp.sort(jnp.abs(v))[::-1].reshape(-1, 128)
    res["segment_sumsq"] = _time(lambda: ops.segment_sumsq(sv))
    # the jnp baseline it replaces (sort-based selection)
    res["argsort_baseline"] = _time(
        lambda: jnp.argsort(-jnp.abs(v)))
    # sort-free selection pipeline (repro.kernels.select) vs the global
    # argsort it retires, swept across gradient scales: exact byte-radix
    # histogram walk, coarse Pallas bucket walk, fixed-shape band
    # extraction, and the u32 key-sort / top_k routes the CPU backend uses
    from repro.kernels import select

    for dexp in (16, 18, 20, 21):
        ds = 1 << dexp
        vs = jax.random.normal(jax.random.PRNGKey(7 + dexp), (ds,))
        ks = max(1, int(0.02 * ds))
        keys = jax.block_until_ready(select.magnitude_keys(vs))
        walk = jax.jit(select.histogram_threshold)
        res[f"select_histogram_walk_d2e{dexp}"] = _time(
            lambda: walk(keys, jnp.int32(ks - 1)), iters=3)
        bucket = jax.jit(lambda vv, r: select.bucket_walk_bounds(vv, r))
        res[f"select_bucket_walk_d2e{dexp}"] = _time(
            lambda: bucket(vs, jnp.int32(ks - 1)), iters=3)
        band = jax.jit(lambda vv, r0, _ks=ks: select.rank_band_indices(
            vv, r0, _ks, impl="sort"))
        res[f"select_band_indices_d2e{dexp}"] = _time(
            lambda: band(vs, jnp.int32(0)), iters=3)
        res[f"select_key_sort_d2e{dexp}"] = _time(
            lambda: jnp.sort(keys), iters=3)
        res[f"select_top_k_d2e{dexp}"] = _time(
            lambda: jax.lax.top_k(jnp.abs(vs), ks), iters=3)
        res[f"select_argsort_baseline_d2e{dexp}"] = _time(
            lambda: jnp.argsort(-jnp.abs(vs)), iters=3)
    # wire-codec bit-packing (repro.comm.pack_kernels): 2-bit ternary planes
    # and 12-bit index streams, the packed-wire encode/decode hot loops
    tern = jax.random.randint(jax.random.PRNGKey(1), (d,), 0, 3,
                              dtype=jnp.uint32)
    res["pack_bits_w2"] = _time(lambda: pack_bits(tern, 2))
    packed2 = pack_bits(tern, 2)
    res["unpack_bits_w2"] = _time(lambda: unpack_bits(packed2, 2, d))
    idx = jax.random.randint(jax.random.PRNGKey(2), (d,), 0, 1 << 12,
                             dtype=jnp.uint32)
    res["pack_bits_w12"] = _time(lambda: pack_bits(idx, 12))
    packed12 = pack_bits(idx, 12)
    res["unpack_bits_w12"] = _time(lambda: unpack_bits(packed12, 12, d))
    # split-plane packing (device-wire index streams: 20-bit at d=1M)
    idx20 = jax.random.randint(jax.random.PRNGKey(5), (d,), 0, 1 << 20,
                               dtype=jnp.uint32)
    res["pack_planes_w20"] = _time(lambda: pack_planes(idx20, 20))
    packed20 = pack_planes(idx20, 20)
    res["unpack_planes_w20"] = _time(lambda: unpack_planes(packed20, 20, d))
    # full codec paths (host-side encode -> Packet -> decode), gradient-sized
    dc = 1 << 18
    vc = jax.random.normal(jax.random.PRNGKey(3), (dc,))
    for cname in ("mlmc_topk", "mlmc_fixed"):
        codec = make_codec(cname, dc, k_fraction=0.01)
        ckey = jax.random.PRNGKey(4)
        res[f"codec_encode_{cname}"] = _time(
            lambda codec=codec: (codec.encode(vc, ckey), 0)[-1], iters=3)
        pkt = codec.encode(vc, ckey).packet
        res[f"codec_decode_{cname}"] = _time(
            lambda codec=codec, pkt=pkt: (codec.decode(pkt), 0)[-1], iters=3)
    # jit-native device codecs (encode -> DevicePacket -> decode, all traced)
    for cname in ("mlmc_topk", "mlmc_fixed", "qsgd"):
        dcodec = make_device_codec(cname, dc, k_fraction=0.01)
        enc = jax.jit(lambda v, k, c=dcodec: c.encode(v, k)[0])
        dec = jax.jit(lambda p, c=dcodec: c.decode(p))
        ckey = jax.random.PRNGKey(6)
        res[f"device_encode_{cname}"] = _time(lambda: enc(vc, ckey), iters=3)
        dpkt = enc(vc, ckey)
        res[f"device_decode_{cname}"] = _time(lambda: dec(dpkt), iters=3)
    # packed-gather operand bytes (what the wire="device" collectives move
    # per worker vs the raw abstract operands), at the tentpole's d = 1M
    dm = 1 << 20
    sm = max(8, int(round(0.001 * dm)))
    topk_raw = 8 * sm                                   # int32 idx + f32 val
    topk_packed = 4 * topk_segment_words(dm, sm, 16)    # 20-bit idx + bf16
    fixed_raw = dm                                      # int8 psum operand
    fixed_packed = 4 * ternary_words(dm)                # 2-bit plane gather
    res_bytes = {
        "topk_gather_raw_bytes": topk_raw,
        "topk_gather_packed_bytes": topk_packed,
        "fixed_psum_int8_bytes": fixed_raw,
        "fixed_gather_packed_bytes": fixed_packed,
    }
    topk_ratio = topk_raw / topk_packed
    fixed_ratio = fixed_raw / fixed_packed
    for k, us in res.items():
        print(f"kernel/{k},{us:.0f},d={d}")
    for k, b in res_bytes.items():
        print(f"kernel/{k},{b},d={dm};s={sm}")
    out = {k: {"us_per_call": u} for k, u in res.items()}
    out.update({k: {"operand_bytes": b} for k, b in res_bytes.items()})
    save_and_print(tag, out,
                   derived=(f"d={d};interpret_mode=True;"
                            f"device_topk_operand_reduction={topk_ratio:.2f}x;"
                            f"device_fixed_operand_reduction={fixed_ratio:.2f}x"))
    return res


if __name__ == "__main__":
    main()
