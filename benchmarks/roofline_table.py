"""Roofline table: aggregates the dry-run JSONs (benchmarks/results/) into
the per-(arch x shape x mesh) three-term roofline report of EXPERIMENTS.md
§Roofline.  Run `python -m repro.launch.dryrun` first to (re)generate."""

import json
import pathlib

RESULTS = pathlib.Path(__file__).parent / "results"


def load_records(method: str | None = None) -> list[dict]:
    recs = []
    for f in sorted(RESULTS.glob("dryrun_*.json")):
        r = json.loads(f.read_text())
        if method and r.get("method") != method:
            continue
        recs.append(r)
    return recs


def format_table(recs: list[dict], mesh: str = "pod16x16") -> str:
    lines = [
        "| arch | shape | bottleneck | t_comp(ms) | t_mem(ms) | t_coll(ms) "
        "| useful | coll_bytes |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP | - | - | - "
                         f"| - | - |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - | - "
                         f"| - | - |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['bottleneck']} "
            f"| {rf['t_compute_s']*1e3:.2f} | {rf['t_memory_s']*1e3:.2f} "
            f"| {rf['t_collective_s']*1e3:.3f} "
            f"| {rf['useful_fraction']:.2f} | {rf['coll_bytes']:.2e} |")
    return "\n".join(lines)


def main(tag="roofline_table") -> None:
    recs = load_records()
    n_ok = sum(1 for r in recs if r["status"] == "ok")
    n_skip = sum(1 for r in recs if r["status"] == "skipped")
    n_err = len(recs) - n_ok - n_skip
    print(format_table(recs))
    print(f"roofline_table,0,ok={n_ok};skipped={n_skip};errors={n_err}")


if __name__ == "__main__":
    main()
