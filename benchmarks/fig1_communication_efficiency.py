"""Figure 1 (BERT/SST-2 stand-in): communication efficiency — quality as a
function of transmitted bits, Adaptive MLMC-Top-k vs Top-k / EF21-SGDM /
Rand-k / uncompressed SGD, at the paper's k = 0.01·n sparsification level."""

from benchmarks.common import run_methods, save_and_print

K = 0.01


def main(tag="fig1_communication_efficiency") -> dict:
    methods = {
        "mlmc_topk_adaptive": dict(method="mlmc_topk", k_fraction=K),
        "topk": dict(method="topk", k_fraction=K),
        "ef21_sgdm": dict(method="ef21_sgdm", k_fraction=K),
        "randk": dict(method="randk", k_fraction=K),
        "sgd_uncompressed": dict(method="dense"),
    }
    res = run_methods(methods)
    # communication efficiency: loss reached per Gbit — MLMC must beat the
    # unbiased strawman (Rand-k) and be far cheaper than dense
    mlmc, randk = res["mlmc_topk_adaptive"], res["randk"]
    dense = res["sgd_uncompressed"]
    derived = (f"mlmc_tail={mlmc['mean_tail_loss']:.4f};"
               f"randk_tail={randk['mean_tail_loss']:.4f};"
               f"bits_vs_dense={dense['total_gbits'] / mlmc['total_gbits']:.0f}x")
    save_and_print(tag, res, derived)
    return res


if __name__ == "__main__":
    main()
