"""Figure 1 (BERT/SST-2 stand-in): communication efficiency — quality as a
function of transmitted bits, Adaptive MLMC-Top-k vs Top-k / EF21-SGDM /
Rand-k / uncompressed SGD, at the paper's k = 0.01·n sparsification level.

Beyond the paper's bit counts, each method's per-step traffic is priced
with the `repro.comm.topology` alpha-beta cost model (star and ring), so
the report includes simulated wall-clock per step — the quantity a
deployment actually optimizes."""

from benchmarks.common import BENCH_WORKERS, run_methods, save_and_print
from repro.comm import simulated_step_time

K = 0.01


def main(tag="fig1_communication_efficiency") -> dict:
    methods = {
        "mlmc_topk_adaptive": dict(method="mlmc_topk", k_fraction=K),
        "topk": dict(method="topk", k_fraction=K),
        "ef21_sgdm": dict(method="ef21_sgdm", k_fraction=K),
        "randk": dict(method="randk", k_fraction=K),
        "sgd_uncompressed": dict(method="dense"),
    }
    res = run_methods(methods)
    for label, r in res.items():
        bits_per_step = r["bits"][-1] / max(len(r["bits"]), 1)
        r["sim_step_ms"] = {
            topo: 1e3 * simulated_step_time(bits_per_step, BENCH_WORKERS,
                                            topology=topo)
            for topo in ("star", "ring")
        }
    # communication efficiency: loss reached per Gbit — MLMC must beat the
    # unbiased strawman (Rand-k) and be far cheaper than dense
    mlmc, randk = res["mlmc_topk_adaptive"], res["randk"]
    dense = res["sgd_uncompressed"]
    derived = (f"mlmc_tail={mlmc['mean_tail_loss']:.4f};"
               f"randk_tail={randk['mean_tail_loss']:.4f};"
               f"bits_vs_dense={dense['total_gbits'] / mlmc['total_gbits']:.0f}x;"
               f"mlmc_star_ms={mlmc['sim_step_ms']['star']:.3f};"
               f"dense_star_ms={dense['sim_step_ms']['star']:.3f}")
    save_and_print(tag, res, derived)
    return res


if __name__ == "__main__":
    main()
