"""Per-leaf codec-policy benchmark — multi-stream aggregation vs the flat wire.

Measures, at the BENCH_wire model sizes:

* trainer steps/s and bits/step of the ``dense_small_tensors`` preset
  (small leaves dense, matmuls mlmc_topk) on the packed multi-stream RCBW
  wire and on the abstract per-segment reference, against the flat
  single-codec ``mlmc_topk`` packed baseline — the acceptance target is
  the policy wire within 20% of the flat path (its per-segment encodes
  reuse the same compiled-codec LRU, so the overhead is container framing
  plus one dispatch per segment);
* single-round aggregate microbenchmarks (µs/round, flat vs policy) at
  the small model's gradient dimension.

Emits a machine-readable ``BENCH_policy.json`` at the REPO ROOT so
successive PRs accumulate a comparable perf record:

    PYTHONPATH=src python -m benchmarks.bench_policy            # full
    PYTHONPATH=src python -m benchmarks.bench_policy --smoke    # CI tier

The smoke tier (a few steps, one size) exercises the emission path on
every push without burning minutes and NEVER clobbers a committed full
record; the weekly full run refreshes the real numbers.
"""

from __future__ import annotations

import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.common import BENCH_WORKERS, run_methods, small_lm_config

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_policy.json"

#: the BENCH_wire sizes, for record-to-record comparability
SIZES = {
    "small": dict(layers=2, d_model=128),
    "wide": dict(layers=2, d_model=256),
}

#: the preset every entry runs (size-ruled: norms/biases dense, matmuls
#: mlmc_topk) — frozen config surface, see tests/test_golden_packets.py
PRESET = "dense_small_tensors"


def _trainer_entries(size_name: str, steps: int) -> dict:
    cfg = small_lm_config(**SIZES[size_name])
    methods = {
        "mlmc_topk_packed_flat": dict(method="mlmc_topk", k_fraction=0.02,
                                      wire="packed"),
        "policy_packed": dict(method="mlmc_topk", k_fraction=0.02,
                              wire="packed", policy=PRESET),
        "policy_abstract": dict(method="mlmc_topk", k_fraction=0.02,
                                policy=PRESET),
    }
    results = run_methods(methods, steps=steps, cfg=cfg)
    out = {}
    for label, r in results.items():
        out[label] = {
            "dim": r["dim"],
            "steps_per_s": round(len(r["loss"]) / max(r["wall_s"], 1e-9), 3),
            "final_loss": round(r["final_loss"], 6),
            "bits_per_step": r["bits"][-1] / max(len(r["bits"]), 1),
        }
    flat = out["mlmc_topk_packed_flat"]["steps_per_s"]
    pol = out["policy_packed"]["steps_per_s"]
    return {
        "trainer": out,
        # acceptance: the multi-stream wire within 20% of the flat path
        "policy_vs_flat_ratio": round(pol / max(flat, 1e-9), 3),
    }


def _round_us(agg, grads, rng, repeats: int = 5) -> float:
    jax.block_until_ready(agg(grads, rng, None).direction)   # warmup
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(agg(grads, rng, None).direction)
        best = min(best, time.perf_counter() - t0)
    return round(best * 1e6, 1)


def _aggregate_micro(dim: int) -> dict:
    """One aggregation round, flat vs policy, packed and abstract — the
    per-round cost of the multi-stream container at a model-sized dim."""
    from repro.comm.policy import CodecPolicy
    from repro.core.aggregators import make_aggregator

    grads = jax.random.normal(jax.random.PRNGKey(0),
                              (BENCH_WORKERS, dim), jnp.float32)
    grads = (grads * jnp.exp(-10.0 * jnp.arange(dim) / dim))
    rng = jax.random.PRNGKey(1)
    # a model-shaped 3-segment split (head dense, middle qsgd, tail mlmc)
    from repro.comm.policy import ResolvedPolicy, Segment

    cut1, cut2 = dim // 16, dim // 4
    policy = ResolvedPolicy(dim, (
        Segment("dense@0", "dense", 0, cut1),
        Segment("qsgd@%d" % cut1, "qsgd", cut1, cut2),
        Segment("mlmc_topk@%d" % cut2, "mlmc_topk", cut2, dim)))
    out = {"segments": len(policy.segments)}
    for wire in ("packed", "abstract"):
        flat = make_aggregator("mlmc_topk", dim, k_fraction=0.02, wire=wire)
        pol = make_aggregator("mlmc_topk", dim, k_fraction=0.02, wire=wire,
                              policy=policy)
        out[f"{wire}_flat_us"] = _round_us(flat, grads, rng)
        out[f"{wire}_policy_us"] = _round_us(pol, grads, rng)
    # the degenerate one-segment policy must cost the flat path exactly
    uni = make_aggregator("mlmc_topk", dim, k_fraction=0.02, wire="packed",
                          policy=CodecPolicy.parse({"*": "mlmc_topk"}))
    out["packed_uniform_policy_us"] = _round_us(uni, grads, rng)
    return out


def main(smoke: bool = False) -> dict:
    steps = 3 if smoke else 12
    sizes = ("small",) if smoke else ("small", "wide")
    record = {
        "benchmark": "policy_multi_stream",
        "smoke": smoke,
        "steps": steps,
        "preset": PRESET,
        "sizes": {},
    }
    for size_name in sizes:
        t0 = time.time()
        entry = _trainer_entries(size_name, steps)
        dim = entry["trainer"]["policy_packed"]["dim"]
        entry["round_us"] = _aggregate_micro(2048 if smoke else dim)
        record["sizes"][size_name] = entry
        for label, r in entry["trainer"].items():
            print(f"bench_policy/{size_name}/{label},"
                  f"{1e6 / max(r['steps_per_s'], 1e-9):.0f},"
                  f"steps_per_s={r['steps_per_s']};"
                  f"final_loss={r['final_loss']:.4f}")
        print(f"# bench_policy {size_name} ratio policy/flat = "
              f"{entry['policy_vs_flat_ratio']} "
              f"({time.time() - t0:.1f}s)", flush=True)
    keep = False
    if smoke and OUT_PATH.exists():
        try:
            # never clobber a committed FULL perf record with a smoke
            # run (CI runs --smoke on every push to test this path)
            keep = not json.loads(OUT_PATH.read_text()).get("smoke", True)
        except (json.JSONDecodeError, OSError):
            pass
    if keep:
        print(f"# smoke run: kept existing full record {OUT_PATH}")
    else:
        OUT_PATH.write_text(json.dumps(record, indent=1) + "\n")
        print(f"# wrote {OUT_PATH}")
    return record


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
