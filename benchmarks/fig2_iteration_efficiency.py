"""Figure 2 (BERT/SST-2 stand-in): iteration efficiency — quality per STEP.
The paper's claim: Adaptive MLMC-Top-k tracks uncompressed SGD per
iteration despite transmitting a tiny fraction of the bits."""

from benchmarks.common import run_methods, save_and_print

K = 0.05


def main(tag="fig2_iteration_efficiency") -> dict:
    res = run_methods({
        "mlmc_topk_adaptive": dict(method="mlmc_topk", k_fraction=K),
        "topk": dict(method="topk", k_fraction=K),
        "randk": dict(method="randk", k_fraction=K),
        "sgd_uncompressed": dict(method="dense"),
    })
    gap = (res["mlmc_topk_adaptive"]["mean_tail_loss"]
           - res["sgd_uncompressed"]["mean_tail_loss"])
    save_and_print(tag, res, derived=f"gap_to_uncompressed={gap:.4f}")
    return res


if __name__ == "__main__":
    main()
