"""Shared benchmark harness.

Each figure-benchmark trains a small model with several aggregation methods
(the paper's comparisons) on synthetic data and reports loss-vs-bits /
loss-vs-iteration telemetry.  Scaled to the CPU container via
REPRO_BENCH_STEPS / REPRO_BENCH_SCALE env vars; the qualitative ordering of
methods is the reproduction target (the paper's hardware runs BERT/ResNet
on GPUs — see DESIGN.md §Assumptions)."""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.data import LMTask, lm_batches
from repro.models import build_model
from repro.optim import sgd
from repro.train import Trainer

RESULTS = pathlib.Path(__file__).parent / "results"
BENCH_STEPS = int(os.environ.get("REPRO_BENCH_STEPS", "30"))
BENCH_WORKERS = int(os.environ.get("REPRO_BENCH_WORKERS", "4"))


def small_lm_config(layers=2, d_model=128, vocab=256) -> ModelConfig:
    return ModelConfig(
        name=f"bench-lm-{layers}x{d_model}",
        family="dense", cite="bench",
        num_layers=layers, d_model=d_model, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=4 * d_model, vocab_size=vocab,
        pattern=(LayerSpec("attn"),))


def run_methods(methods: dict[str, dict], *, steps=None, workers=None,
                lr=0.05, seq=32, batch_per_worker=4, seed=0,
                cfg: ModelConfig | None = None) -> dict:
    """Train one fresh model per method; return per-method histories.

    methods: {label: kwargs for Trainer (must include 'method')}."""
    steps = steps or BENCH_STEPS
    workers = workers or BENCH_WORKERS
    cfg = cfg or small_lm_config()
    model = build_model(cfg)
    task = LMTask(vocab=cfg.vocab_size, seq=seq)

    out = {}
    for label, kw in methods.items():
        params = model.init(jax.random.PRNGKey(seed))

        def loss_fn(p, batch):
            return model.loss(p, batch, remat=False)[0]

        t0 = time.time()
        trainer = Trainer(loss_fn, params, num_workers=workers,
                          optimizer=sgd(lr), **kw)
        data = lm_batches(task, workers, batch_per_worker, seed=seed)
        hist = trainer.fit(data, steps=steps, seed=seed + 1)
        out[label] = {
            "loss": hist.loss, "bits": hist.bits,
            "final_loss": hist.loss[-1],
            "mean_tail_loss": float(jnp.mean(jnp.asarray(hist.loss[-5:]))),
            "total_gbits": hist.bits[-1] / 1e9,
            "wall_s": round(time.time() - t0, 1),
            "dim": trainer.dim,
        }
    return out


def save_and_print(name: str, results: dict, derived: str = "") -> None:
    RESULTS.mkdir(exist_ok=True, parents=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(results, indent=1))
    for label, r in results.items():
        if isinstance(r, dict) and "mean_tail_loss" in r:
            print(f"{name}/{label},{r['wall_s'] * 1e6 / max(len(r['loss']), 1):.0f},"
                  f"tail_loss={r['mean_tail_loss']:.4f};gbits={r['total_gbits']:.4f}")
    if derived:
        print(f"{name},0,{derived}")
