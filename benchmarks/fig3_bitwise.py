"""Figure 3 (CIFAR/ResNet18 stand-in): bit-wise compression — fixed-point
MLMC (Alg. 2, Lemma 3.3 probabilities) vs biased 2-bit fixed-point
quantization vs unbiased 2-bit QSGD vs uncompressed SGD."""

from benchmarks.common import run_methods, save_and_print


def main(tag="fig3_bitwise") -> dict:
    res = run_methods({
        "mlmc_fixed_point": dict(method="mlmc_fixed"),
        "fixed_2bit": dict(method="fixed2"),
        "qsgd_2bit": dict(method="qsgd", qsgd_levels=2),
        "sgd_uncompressed": dict(method="dense"),
    })
    derived = (f"mlmc_gbits={res['mlmc_fixed_point']['total_gbits']:.4f};"
               f"dense_gbits={res['sgd_uncompressed']['total_gbits']:.4f}")
    save_and_print(tag, res, derived)
    return res


if __name__ == "__main__":
    main()
