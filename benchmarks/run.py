"""Benchmark entrypoint: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig1 fig3  # subset
Budget via REPRO_BENCH_STEPS (default 40) / REPRO_BENCH_WORKERS (4)."""

import sys
import time


def main() -> None:
    from benchmarks import (
        bench_adaptive,
        bench_wire,
        fig1_communication_efficiency,
        fig2_iteration_efficiency,
        fig3_bitwise,
        fig4_cifar_sparsification,
        fig6_rtn,
        kernel_bench,
        parallelization_scaling,
        roofline_table,
        variance_table,
    )

    benches = {
        "variance_table": variance_table.main,        # Lemmas 3.3/3.4/3.6
        "fig1": fig1_communication_efficiency.main,   # Fig. 1
        "fig2": fig2_iteration_efficiency.main,       # Fig. 2
        "fig3": fig3_bitwise.main,                    # Fig. 3
        "fig4": fig4_cifar_sparsification.main,       # Figs. 4-5 (App. G.1)
        "fig6": fig6_rtn.main,                        # Fig. 6 (App. G.2)
        "parallelization": parallelization_scaling.main,  # Thm 4.1 / §4
        "kernels": kernel_bench.main,                 # Pallas hot-spots
        "roofline": roofline_table.main,              # §Roofline aggregate
        "adaptive": bench_adaptive.main,              # BENCH_adaptive.json
        "wire": bench_wire.main,                      # BENCH_wire.json
    }
    picks = sys.argv[1:] or list(benches)
    print("name,us_per_call,derived")
    for name in picks:
        t0 = time.time()
        try:
            benches[name]()
        except Exception as e:  # keep the suite going; report the failure
            print(f"{name},0,ERROR={type(e).__name__}:{e}")
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
