"""Adaptive vs fixed-probability MLMC — the perf-trajectory benchmark.

Trains the stateful EMA-adaptive family (`mlmc_adaptive_topk`, Lemma 3.4 /
Alg. 3 with the CommState ladder) against the fixed-probability variant
(`mlmc_topk_static`, Alg. 2) and the stateless per-sample adaptive
(`mlmc_topk`) at TWO model sizes, and emits a machine-readable
``BENCH_adaptive.json`` at the REPO ROOT so successive PRs accumulate a
comparable perf record: steps/s, bits/step, and final loss per method/size.

    PYTHONPATH=src python -m benchmarks.bench_adaptive            # full
    PYTHONPATH=src python -m benchmarks.bench_adaptive --smoke    # CI tier

The smoke tier (a few steps, one size) exists so ci.yml exercises the
emission path on every push without burning minutes; the weekly full run
refreshes the real numbers.
"""

from __future__ import annotations

import json
import pathlib
import sys
import time

from benchmarks.common import BENCH_STEPS, run_methods, small_lm_config

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_adaptive.json"

#: the comparison the paper's headline empirical win rests on (§5, Fig. 2)
METHODS = {
    "mlmc_adaptive_topk": dict(method="mlmc_adaptive_topk", k_fraction=0.02,
                               ema_rho=0.25),
    "mlmc_topk": dict(method="mlmc_topk", k_fraction=0.02),
    "mlmc_topk_static": dict(method="mlmc_topk_static", k_fraction=0.02),
}

#: two sizes so the trajectory tracks both the tiny and the wider regime
SIZES = {
    "small": dict(layers=2, d_model=128),
    "wide": dict(layers=2, d_model=256),
}


def main(smoke: bool = False) -> dict:
    steps = 6 if smoke else BENCH_STEPS
    sizes = {"small": SIZES["small"]} if smoke else SIZES
    record = {
        "benchmark": "adaptive_vs_fixed_mlmc",
        "smoke": smoke,
        "steps": steps,
        "sizes": {},
    }
    for size_name, size_kw in sizes.items():
        cfg = small_lm_config(**size_kw)
        t0 = time.time()
        results = run_methods(METHODS, steps=steps, cfg=cfg)
        for label, r in results.items():
            entry = {
                "dim": r["dim"],
                "steps_per_s": round(len(r["loss"]) / max(r["wall_s"], 1e-9),
                                     3),
                "bits_per_step": r["bits"][-1] / max(len(r["bits"]), 1),
                "final_loss": round(r["final_loss"], 6),
                "mean_tail_loss": round(r["mean_tail_loss"], 6),
            }
            record["sizes"].setdefault(size_name, {})[label] = entry
            print(f"bench_adaptive/{size_name}/{label},"
                  f"{1e6 / max(entry['steps_per_s'], 1e-9):.0f},"
                  f"final_loss={entry['final_loss']:.4f};"
                  f"bits_per_step={entry['bits_per_step']:.3e}")
        print(f"# bench_adaptive {size_name} took {time.time()-t0:.1f}s",
              flush=True)
    if smoke and OUT_PATH.exists():
        try:
            if not json.loads(OUT_PATH.read_text()).get("smoke", True):
                # never clobber a committed FULL perf record with a smoke
                # run (CI runs --smoke on every push to test this path)
                print(f"# smoke run: kept existing full record {OUT_PATH}")
                return record
        except (json.JSONDecodeError, OSError):
            pass
    OUT_PATH.write_text(json.dumps(record, indent=1) + "\n")
    print(f"# wrote {OUT_PATH}")
    return record


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
