"""Figures 4-5 (CIFAR/ResNet18 sparsification, App. G.1 stand-in): the same
method set as Fig. 1 on a SECOND task family (teacher-student regression
MLP) at the paper's smaller k = 0.005·n level — checks the ordering is not
an artifact of the LM task."""

import jax
import jax.numpy as jnp

from benchmarks.common import BENCH_STEPS, BENCH_WORKERS, save_and_print
from repro.data import TeacherTask, teacher_student
from repro.optim import sgd
from repro.train import Trainer

K = 0.005


def _mlp_init(key, dims=(32, 128, 128, 1)):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": jax.random.normal(k, (a, b)) / a**0.5,
             "b": jnp.zeros((b,))}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _mlp_loss(params, batch):
    x = batch["x"]
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1:
            x = jax.nn.gelu(x)
    return jnp.mean((x - batch["y"]) ** 2)


def main(tag="fig4_cifar_sparsification") -> dict:
    task = TeacherTask()
    res = {}
    for label, kw in {
        "mlmc_topk_adaptive": dict(method="mlmc_topk", k_fraction=K),
        "topk": dict(method="topk", k_fraction=K),
        "ef21_sgdm": dict(method="ef21_sgdm", k_fraction=K),
        "randk": dict(method="randk", k_fraction=K),
        "sgd_uncompressed": dict(method="dense"),
    }.items():
        params = _mlp_init(jax.random.PRNGKey(0))
        tr = Trainer(_mlp_loss, params, num_workers=BENCH_WORKERS,
                     optimizer=sgd(0.05), **kw)
        data = teacher_student(task, BENCH_WORKERS, 16)
        hist = tr.fit(data, steps=BENCH_STEPS * 3)
        res[label] = {"loss": hist.loss, "bits": hist.bits,
                      "final_loss": hist.loss[-1],
                      "mean_tail_loss": float(jnp.mean(
                          jnp.asarray(hist.loss[-10:]))),
                      "total_gbits": hist.bits[-1] / 1e9,
                      "wall_s": 0.0, "dim": tr.dim}
    import math

    randk_tail = res["randk"]["mean_tail_loss"]
    if math.isnan(randk_tail):
        randk_tail = float("inf")   # Rand-k diverged (omega = d/k variance)
    ordering = res["mlmc_topk_adaptive"]["mean_tail_loss"] <= randk_tail * 1.2
    save_and_print(tag, res, derived=f"mlmc_beats_randk={ordering}")
    return res


if __name__ == "__main__":
    main()
