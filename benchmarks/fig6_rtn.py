"""Figure 6 (BERT/SST-2 RTN stand-in, App. G.2): Adaptive MLMC-RTN vs plain
RTN at l ∈ {2,4,8} vs uncompressed SGD."""

from benchmarks.common import run_methods, save_and_print


def main(tag="fig6_rtn") -> dict:
    res = run_methods({
        "mlmc_rtn_adaptive": dict(method="mlmc_rtn"),
        "rtn_l2": dict(method="rtn", rtn_level=2),
        "rtn_l4": dict(method="rtn", rtn_level=4),
        "rtn_l8": dict(method="rtn", rtn_level=8),
        "sgd_uncompressed": dict(method="dense"),
    })
    derived = (f"mlmc_gbits={res['mlmc_rtn_adaptive']['total_gbits']:.4f};"
               f"rtn8_gbits={res['rtn_l8']['total_gbits']:.4f}")
    save_and_print(tag, res, derived)
    return res


if __name__ == "__main__":
    main()
