"""Figure 6 (BERT/SST-2 RTN stand-in, App. G.2): Adaptive MLMC-RTN vs plain
RTN at l ∈ {2,4,8} vs uncompressed SGD.

Bit accounting note: mlmc_rtn books the HONEST per-draw wire cost
(`core.bits.rtn_mlmc_bits`, ~(l+2) bits/entry — level-l grid codes plus the
{-1,0,+1} refinement correction the byte codec actually ships).  Earlier
revisions reused the 2d fixed-point-analogy entry, which understated
mlmc_gbits for every draw above level 1; comparisons against older saved
results should expect a higher (truthful) mlmc_gbits."""

from benchmarks.common import run_methods, save_and_print


def main(tag="fig6_rtn") -> dict:
    res = run_methods({
        "mlmc_rtn_adaptive": dict(method="mlmc_rtn"),
        "rtn_l2": dict(method="rtn", rtn_level=2),
        "rtn_l4": dict(method="rtn", rtn_level=4),
        "rtn_l8": dict(method="rtn", rtn_level=8),
        "sgd_uncompressed": dict(method="dense"),
    })
    derived = (f"mlmc_gbits={res['mlmc_rtn_adaptive']['total_gbits']:.4f};"
               f"rtn8_gbits={res['rtn_l8']['total_gbits']:.4f};"
               "ledger=honest_rtn_mlmc_bits")
    save_and_print(tag, res, derived)
    return res


if __name__ == "__main__":
    main()
