"""Theorem 4.1 / §4 parallelization: MLMC (unbiased) keeps improving as the
worker count M grows (error ∝ 1/sqrt(MT) with no bias floor), which is the
paper's massive-parallelization argument vs EF21-SGDM's O(N^{1/3}) cap.

We train the same model at fixed per-worker batch for M ∈ {2, 8} and check
the M=8 run reaches a lower tail loss for the MLMC method.  The MLMC method
also runs on the jit-native device wire (``wire="device"``: bit-packed
collective operands, repro.comm.device_wire), recording the MEASURED
operand bytes/step each worker count actually moves."""

from benchmarks.common import BENCH_STEPS, run_methods, save_and_print


def main(tag="parallelization_scaling") -> dict:
    out = {}
    for m in (2, 8):
        res = run_methods(
            {"mlmc": dict(method="mlmc_topk", k_fraction=0.02),
             "mlmc_device": dict(method="mlmc_topk", k_fraction=0.02,
                                 wire="device"),
             "ef21_sgdm": dict(method="ef21_sgdm", k_fraction=0.02)},
            workers=m, steps=BENCH_STEPS)
        out[f"M={m}"] = {k: {"mean_tail_loss": v["mean_tail_loss"],
                             "total_gbits": v["total_gbits"],
                             "loss": v["loss"], "wall_s": v["wall_s"]}
                         for k, v in res.items()}
        # measured per-step collective operand bytes (all M workers): only
        # the device wire measures packet shapes; the other entries book
        # core.bits formulas and stay gbits-only
        out[f"M={m}"]["mlmc_device"]["operand_bytes_per_step"] = (
            res["mlmc_device"]["bits"][-1] / 8.0
            / max(len(res["mlmc_device"]["bits"]), 1))
    improves = (out["M=8"]["mlmc"]["mean_tail_loss"]
                <= out["M=2"]["mlmc"]["mean_tail_loss"] + 0.05)
    dev8 = out["M=8"]["mlmc_device"]["operand_bytes_per_step"]
    save_and_print(tag, out,
                   derived=(f"mlmc_improves_with_M={improves};"
                            f"device_operand_bytes_per_step_M8={dev8:.0f}"))
    return out


if __name__ == "__main__":
    main()
