"""Downlink-compression benchmark — uplink-only vs bidirectional.

The PR-7 downlink ships the server direction as a DIANA-shift compressed
RCD2 blob instead of the raw f32 broadcast.  This benchmark trains the
same model both ways at the two BENCH_wire sizes and reports, per entry:

* ``bytes_down_per_step`` straight from the transport's stats ledger (the
  loopback transport books the raw f32 broadcast for uplink-only and the
  real framed blob size for bidirectional — the same booking the tcp star
  applies to actual socket traffic);
* ``steps_per_s`` and ``final_loss`` so the bytes saving is read next to
  its convergence cost (the acceptance gate: compressed downlink bytes
  below the f32 baseline at equal final-loss tolerance).

Emits ``BENCH_downlink.json`` at the REPO ROOT:

    PYTHONPATH=src python -m benchmarks.bench_downlink            # full
    PYTHONPATH=src python -m benchmarks.bench_downlink --smoke    # CI tier

The smoke tier never clobbers a committed full record (same contract as
``bench_wire`` / ``bench_adaptive``).
"""

from __future__ import annotations

import json
import pathlib
import sys
import time

import jax

from benchmarks.common import BENCH_WORKERS, small_lm_config
from repro.data import LMTask, lm_batches
from repro.models import build_model
from repro.optim import sgd
from repro.train import Trainer

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_downlink.json"

SIZES = {
    "small": dict(layers=2, d_model=128),
    "wide": dict(layers=2, d_model=256),
}

#: the two sides of the comparison: identical uplink (packed mlmc_topk),
#: downlink raw f32 broadcast vs DIANA-shift compressed Top-k
METHODS = {
    "uplink_only": dict(method="mlmc_topk", k_fraction=0.02, wire="packed"),
    "bidirectional": dict(method="mlmc_topk", k_fraction=0.02, wire="packed",
                          downlink="topk"),
}


def _run_one(cfg, kw: dict, steps: int, *, workers: int, seed: int = 0):
    model = build_model(cfg)
    task = LMTask(vocab=cfg.vocab_size, seq=32)
    params = model.init(jax.random.PRNGKey(seed))

    def loss_fn(p, batch):
        return model.loss(p, batch, remat=False)[0]

    trainer = Trainer(loss_fn, params, num_workers=workers,
                      optimizer=sgd(0.05), **kw)
    data = lm_batches(task, workers, 4, seed=seed)
    t0 = time.time()
    hist = trainer.fit(data, steps=steps, seed=seed + 1)
    wall = time.time() - t0
    stats = trainer.transport.stats
    return {
        "dim": trainer.dim,
        "steps_per_s": round(len(hist.loss) / max(wall, 1e-9), 3),
        "final_loss": round(hist.loss[-1], 6),
        "bytes_up_per_step": stats.bytes_up // max(steps, 1),
        "bytes_down_per_step": stats.bytes_down // max(steps, 1),
    }


def _size_entry(size_name: str, steps: int) -> dict:
    cfg = small_lm_config(**SIZES[size_name])
    out = {label: _run_one(cfg, kw, steps, workers=BENCH_WORKERS)
           for label, kw in METHODS.items()}
    up, bi = out["uplink_only"], out["bidirectional"]
    # the uplink-only broadcast IS the f32 baseline: 4*dim bytes per rank
    assert up["bytes_down_per_step"] == 4 * up["dim"] * BENCH_WORKERS
    return {
        "trainer": out,
        # acceptance: compressed downlink bytes below the f32 baseline...
        "down_bytes_ratio": round(bi["bytes_down_per_step"]
                                  / max(up["bytes_down_per_step"], 1), 4),
        # ...at equal final-loss tolerance (reader-side judgement call;
        # both numbers are in the record)
        "final_loss_delta": round(bi["final_loss"] - up["final_loss"], 6),
    }


def main(smoke: bool = False) -> dict:
    steps = 3 if smoke else 12
    sizes = ("small",) if smoke else ("small", "wide")
    record = {"benchmark": "downlink", "smoke": smoke, "steps": steps,
              "workers": BENCH_WORKERS, "sizes": {}}
    for size_name in sizes:
        t0 = time.time()
        entry = _size_entry(size_name, steps)
        record["sizes"][size_name] = entry
        for label, r in entry["trainer"].items():
            print(f"bench_downlink/{size_name}/{label},"
                  f"{1e6 / max(r['steps_per_s'], 1e-9):.0f},"
                  f"down_Bps={r['bytes_down_per_step']};"
                  f"final_loss={r['final_loss']:.4f}")
        print(f"# bench_downlink {size_name} down-bytes ratio = "
              f"{entry['down_bytes_ratio']} ({time.time() - t0:.1f}s)",
              flush=True)
    keep = False
    if smoke and OUT_PATH.exists():
        try:
            # never clobber a committed FULL perf record with a smoke run
            keep = not json.loads(OUT_PATH.read_text()).get("smoke", True)
        except (json.JSONDecodeError, OSError):
            pass
    if keep:
        print(f"# smoke run: kept existing full record {OUT_PATH}")
    else:
        OUT_PATH.write_text(json.dumps(record, indent=1) + "\n")
        print(f"# wrote {OUT_PATH}")
    return record


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
