"""Batched serving demo: prefill a batch of prompts through a reduced
assigned architecture, then greedy-decode continuations through the cache
machinery (KV ring buffers / SSM state / MLA latents — pick any family).

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-370m
    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-27b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke
from repro.models import build_model
from repro.serve import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.num_vision_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["source"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.encoder.max_source_len,
                  cfg.encoder.d_model))

    engine = Engine(model, params)
    t0 = time.time()
    out = engine.generate(batch, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    toks = out.tokens
    print(f"arch={cfg.name} ({cfg.family}), batch={args.batch}, "
          f"prompt={args.prompt_len}, generated={toks.shape[1]} tokens")
    for b in range(min(args.batch, 2)):
        print(f"  seq[{b}]: {list(map(int, toks[b]))}")
    print(f"{args.batch * toks.shape[1] / dt:.1f} tok/s "
          f"(CPU, reduced config)")
    assert toks.shape == (args.batch, args.new_tokens)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab_size)))
    print("OK")


if __name__ == "__main__":
    main()
