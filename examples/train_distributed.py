"""End-to-end driver: train a ~100M-parameter transformer with
MLMC-compressed distributed SGD (Alg. 3) for a few hundred steps, simulated
over M workers, tracking loss AND transmitted bits; saves a checkpoint.

Full run (~100M params, 300 steps — budget a few hours on 1 CPU core):
    PYTHONPATH=src python examples/train_distributed.py --full
Quick run (default; ~2 min, ~1M params, 30 steps):
    PYTHONPATH=src python examples/train_distributed.py
"""

import argparse
import time

import jax

from repro import checkpoint
from repro.configs import get_config, reduce_for_smoke
from repro.data import LMTask, lm_batches
from repro.models import build_model
from repro.optim import sgd
from repro.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~110M-param paper-scale config, 300 steps")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--method", default="mlmc_topk")
    ap.add_argument("--k-fraction", type=float, default=0.01)
    args = ap.parse_args()

    cfg = get_config("paper-scale")
    if not args.full:
        cfg = reduce_for_smoke(cfg)
    steps = args.steps or (300 if args.full else 30)
    seq = 128 if args.full else 32

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M workers={args.workers} "
          f"method={args.method} steps={steps}")

    def loss_fn(p, batch):
        return model.loss(p, batch, remat=False)[0]

    trainer = Trainer(loss_fn, params, num_workers=args.workers,
                      method=args.method, optimizer=sgd(0.05),
                      k_fraction=args.k_fraction)
    data = lm_batches(LMTask(vocab=cfg.vocab_size, seq=seq),
                      args.workers, 2)
    t0 = time.time()
    hist = trainer.fit(data, steps=steps, log_every=max(steps // 10, 1))
    dt = time.time() - t0

    print(f"\nloss {hist.loss[0]:.3f} -> {hist.loss[-1]:.3f} in {dt:.0f}s")
    print(f"transmitted {hist.bits[-1]/1e9:.3f} Gbit "
          f"(dense would be {32 * trainer.dim * args.workers * steps / 1e9:.1f} Gbit)")
    checkpoint.save("checkpoints/train_distributed", trainer.params,
                    {"arch": cfg.name, "method": args.method,
                     "steps": steps, "final_loss": hist.loss[-1],
                     "total_bits": hist.bits[-1]})
    print("checkpoint -> checkpoints/train_distributed.npz")
    assert hist.loss[-1] < hist.loss[0], "training must reduce loss"


if __name__ == "__main__":
    main()
