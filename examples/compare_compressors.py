"""Compare every aggregation method on one task — the paper's Figure-1
experiment in miniature, printed as a table.

    PYTHONPATH=src python examples/compare_compressors.py --steps 40
"""

import argparse

import jax

from repro.data import LMTask, lm_batches
from repro.models import build_model
from repro.optim import sgd
from repro.train import Trainer
from benchmarks.common import small_lm_config

METHODS = ["dense", "mlmc_topk", "mlmc_topk_static", "mlmc_fixed",
           "mlmc_rtn", "topk", "randk", "qsgd", "ef21", "ef21_sgdm"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--k-fraction", type=float, default=0.02)
    args = ap.parse_args()

    cfg = small_lm_config()
    model = build_model(cfg)
    task = LMTask(vocab=cfg.vocab_size, seq=32)

    print(f"{'method':20s} {'final_loss':>10s} {'Gbits':>10s} {'vs dense':>9s}")
    dense_bits = None
    for method in METHODS:
        params = model.init(jax.random.PRNGKey(0))
        tr = Trainer(lambda p, b: model.loss(p, b, remat=False)[0], params,
                     num_workers=args.workers, method=method,
                     optimizer=sgd(0.05), k_fraction=args.k_fraction)
        data = lm_batches(task, args.workers, 4)
        hist = tr.fit(data, steps=args.steps)
        gb = hist.bits[-1] / 1e9
        if method == "dense":
            dense_bits = gb
        ratio = f"{dense_bits / gb:7.0f}x" if dense_bits else "-"
        print(f"{method:20s} {hist.loss[-1]:10.4f} {gb:10.4f} {ratio:>9s}")


if __name__ == "__main__":
    main()
