"""Quickstart: the MLMC compression block in 40 lines.

Takes a gradient-like vector, builds the multilevel s-Top-k family, draws
MLMC estimates with the adaptive (Lemma 3.4) level distribution, and shows
(1) unbiasedness, (2) the tiny per-step payload, (3) the variance win over
Rand-k at the same budget.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import RandK, STopKMultilevel, adaptive_probs, mlmc_estimate
from repro.core.bits import dense_bits, topk_mlmc_bits

d, s = 8192, 64
key = jax.random.PRNGKey(0)
# a deep-learning-like gradient: exponentially decaying sorted magnitudes
v = jax.random.normal(key, (d,)) * jnp.exp(-0.002 * jnp.arange(d))

comp = STopKMultilevel(d=d, s=s)
probs = adaptive_probs(comp, v)
print(f"levels L = {comp.num_levels}; adaptive p_1..4 = {probs[:4]}")

keys = jax.random.split(jax.random.PRNGKey(1), 2000)
estimates = jax.vmap(
    lambda k: mlmc_estimate(comp, v, k, adaptive=True).estimate)(keys)

rel_bias = float(jnp.linalg.norm(estimates.mean(0) - v) / jnp.linalg.norm(v))
mlmc_mse = float(jnp.mean(jnp.sum((estimates - v) ** 2, -1)))

randk = RandK(s)  # same per-step budget: s entries
rk = jax.vmap(lambda k: randk.compress(v, rng=k))(keys)
randk_mse = float(jnp.mean(jnp.sum((rk - v) ** 2, -1)))

print(f"unbiasedness: |E[g~] - v|/|v| = {rel_bias:.4f}  (-> 0 with samples)")
print(f"payload: {topk_mlmc_bits(d, s)/1e3:.2f} kbit/step vs "
      f"{dense_bits(d)/1e3:.1f} kbit uncompressed "
      f"({dense_bits(d)/topk_mlmc_bits(d, s):.0f}x)")
print(f"MSE at equal budget: MLMC {mlmc_mse:.3f} vs Rand-k {randk_mse:.3f} "
      f"({randk_mse/mlmc_mse:.1f}x lower)")
assert rel_bias < 0.1 and mlmc_mse < randk_mse
print("OK")
